"""Fig. 3, live: sweep the working-set size and watch the adaptive policy
switch between the offload and unload paths.

Reproduces the paper's core result with the calibrated simulator + the real
decision-module code: offload wins at small region counts (MTT-resident),
unload wins at large ones (translation misses), adaptive tracks the best —
and beats both in the crossover zone.

Run:  PYTHONPATH=src python examples/adaptive_unload_demo.py
"""
import jax
import jax.numpy as jnp

from repro.core import sweep_point
from repro.core.policy import get_policy_factory

N, WARM = 50_000, 5_000
TOP_K = 4096

# policies resolved from the registry — the same names engine configs use
offload_policy = get_policy_factory("always-offload")()
unload_policy = get_policy_factory("always-unload")()

print(f"{'regions':>10s} {'offload':>9s} {'unload':>9s} {'adaptive':>9s}  winner")
for log2r in (0, 6, 12, 14, 17, 20):
    r = 2 ** log2r
    key = jax.random.key(r)
    off, _ = sweep_point(key, r, N, WARM, offload_policy)
    un, _ = sweep_point(key, r, N, WARM, unload_policy)
    hot = jnp.zeros((r,), bool).at[: min(TOP_K, r)].set(True)
    ad, res = sweep_point(key, r, N, WARM,
                          get_policy_factory("hint")(hot_regions=hot))
    frac_unloaded = float(res.n_unloaded) / (float(res.n_offloaded) + float(res.n_unloaded))
    winner = "adaptive" if ad <= min(off, un) else ("offload" if off < un else "unload")
    print(f"{f'2^{log2r}':>10s} {off:8.2f}µ {un:8.2f}µ {ad:8.2f}µ  {winner}"
          f"  ({frac_unloaded:.0%} writes unloaded)")

r = 2 ** 20
key = jax.random.key(1)
off, _ = sweep_point(key, r, N, WARM, offload_policy)
un, _ = sweep_point(key, r, N, WARM, unload_policy)
print(f"\nimprovement at 2^20 regions: {1 - un / off:.1%} (paper: up to 31%)")
