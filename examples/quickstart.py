"""Quickstart: the uRDMA bidirectional write engine in 60 seconds.

Shows the paper's three pieces working together on CPU:
  1. register destination memory in uMTT (security parity),
  2. route a Zipfian write stream through the decision module
     (frequency policy over heavy-hitter counters),
  3. observe path statistics + verify the memory matches a last-write-wins
     oracle (functional parity, regardless of which path each write took).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DecisionModule,
    RemoteWriteEngine,
    make_umtt,
    make_write_batch,
    register,
)

R, W, BATCH, STEPS = 256, 32, 64, 40

# -- setup: register [0, R) under stag 7 (paper: registration at setup time)
table = register(make_umtt(64), base=0, n_regions=R, stag=7)

# decision plane from registry names: the 'adaptive' write path paired
# with the paper's frequency policy over exact heavy-hitter counters
engine = RemoteWriteEngine(
    decision=DecisionModule.from_names(
        "frequency", path="adaptive", n_regions=R, hot_threshold=4),
    ring_capacity=256,
    width=W,
)
state = engine.init_state(table)
mem = jnp.zeros((R, W))

# -- drive a skewed write stream (hot head, cold tail — like the paper's Zipf)
rng = np.random.RandomState(0)
oracle = np.zeros((R, W))
for step in range(STEPS):
    regions = jnp.asarray(rng.zipf(1.3, BATCH) % R, jnp.int32)
    payload = jnp.asarray(rng.randn(BATCH, W), jnp.float32)
    batch = make_write_batch(regions, size=jnp.full((BATCH,), W, jnp.int32))
    state, mem = engine.write(state, mem, batch, payload,
                              jnp.full((BATCH,), 7, jnp.int32))
    for i in range(BATCH):
        oracle[int(regions[i])] = payload[i]

state, mem = engine.flush(state, mem)

total = int(state.n_offloaded) + int(state.n_unloaded)
print(f"writes routed:   {total}")
print(f"  offload path:  {int(state.n_offloaded)} (hot destinations)")
print(f"  unload path:   {int(state.n_unloaded)} (cold destinations)")
print(f"  rejected:      {int(state.n_rejected)} (uMTT security check)")
print(f"functional parity vs oracle: {np.allclose(np.asarray(mem), oracle)}")
