"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Exercises the full production stack on CPU: config -> model -> synthetic
data pipeline -> AdamW + warmup-cosine -> microbatched train_step with
remat -> fault-tolerant Trainer (async checkpoints + resume + straggler
EWMA). For the MoE variant (--arch granite-moe-3b-a800m) the adaptive
expert-dispatch hot-mask updates from the monitor every step.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch ...]
"""
import argparse
import dataclasses
import logging
import tempfile

import jax

from repro.configs import get_config
from repro.data import DataConfig, Pipeline, SyntheticSource
from repro.models import build_model
from repro.optim import AdamW, warmup_cosine
from repro.train import Trainer, TrainerConfig, init_train_state, make_train_step

logging.basicConfig(level=logging.INFO, format="%(message)s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--microbatches", type=int, default=2)
    args = ap.parse_args()

    # ~100M-scale variant of the assigned arch: same structure, wider than
    # the smoke config
    cfg = dataclasses.replace(
        get_config(args.arch).reduced(),
        n_layers=8, d_model=512, vocab=8192,
        n_heads=8, n_kv_heads=4, head_dim=64, d_ff=1408,
    )
    model = build_model(cfg)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"tokens/step={args.batch * args.seq}")

    opt = AdamW(lr=warmup_cosine(3e-4, 20, args.steps), weight_decay=0.1)
    state = init_train_state(model, opt, jax.random.key(0), args.seq,
                             n_hot_experts=2 if cfg.n_experts else 0)
    step = jax.jit(make_train_step(model, opt, microbatches=args.microbatches,
                                   n_hot_experts=2 if cfg.n_experts else 0))

    dc = DataConfig(seq_len=args.seq, global_batch=args.batch, vocab=cfg.vocab)
    pipe = Pipeline(SyntheticSource(dc)).start()
    with tempfile.TemporaryDirectory() as ckpt_dir:
        trainer = Trainer(step, state, pipe, TrainerConfig(
            total_steps=args.steps, checkpoint_every=100,
            checkpoint_dir=ckpt_dir, log_every=20,
        ))
        result = trainer.run()
    pipe.stop()
    print(f"final loss {result['final_loss']:.4f} after {result['steps']} steps "
          f"(start {trainer.history[0]:.4f})")
    assert result["final_loss"] < trainer.history[0], "loss should decrease"


if __name__ == "__main__":
    main()
