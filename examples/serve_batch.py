"""Batched serving with uRDMA KV-write routing.

Prefills a batch of prompts, then decodes with each of the three write
modes — direct (offload), staged (unload: ring + bulk drain), adaptive
(page-frequency policy) — verifying all three emit IDENTICAL tokens
(path choice is invisible to the application: paper Idea 3).

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeConfig, ServeEngine


def main() -> None:
    cfg = get_config("qwen2-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), 128)
    prompts = jax.random.randint(jax.random.key(1), (8, 24), 0, cfg.vocab)

    outs = {}
    for mode in ("direct", "staged", "adaptive"):
        # hot_threshold is counted over per-sequence page writes: with B=8
        # sequences hitting the same page each step, a fresh page needs
        # threshold/B steps to turn hot — 24 keeps new pages cold (staged)
        # for a few steps before the frequency policy flips them to direct
        eng = ServeEngine(model, params, ServeConfig(
            max_seq=128, write_mode=mode, ring_size=8, page_size=16,
            hot_threshold=24,
        ))
        outs[mode] = eng.generate(prompts, 32)
        s = eng.stats
        print(f"{mode:9s} tokens={outs[mode].shape} "
              f"direct={s['direct_writes']} staged={s['staged_writes']} "
              f"drains={s['drains']}")

    same_sd = bool(jnp.all(outs["direct"] == outs["staged"]))
    same_da = bool(jnp.all(outs["direct"] == outs["adaptive"]))
    print(f"identical tokens across write paths: staged={same_sd} adaptive={same_da}")
    assert same_sd and same_da


if __name__ == "__main__":
    main()
