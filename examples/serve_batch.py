"""Batched serving with uRDMA KV-write routing, through the Engine facade.

Serves the same 8 prompts under each registered write path — direct
(offload), staged (unload: ring + bulk drain), adaptive (page-frequency
policy) — and verifies all three produce IDENTICAL token streams (path
choice is invisible to the application: paper Idea 3). Each Completion
carries its own telemetry: TTFT and how its KV writes were routed.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import numpy as np

from repro.serve import Engine, EngineConfig, SamplingParams, build_model_and_params


def main() -> None:
    max_seq = 128
    cfg, model, params = build_model_and_params("qwen2-7b", max_seq)
    rng = np.random.RandomState(1)
    prompts = [rng.randint(0, cfg.vocab, size=24) for _ in range(8)]

    outs = {}
    for path in ("direct", "staged", "adaptive"):
        # hot_threshold is counted over physical pool blocks: prefill
        # heats each prompt's blocks past 12 at admission, while a block
        # a slot decodes into starts cold (staged) and flips to the
        # direct path after a dozen writes land in it
        eng = Engine.from_config(EngineConfig(
            max_seq=max_seq, n_slots=8, path=path, ring_size=8,
            page_size=16, hot_threshold=12,
        ), model, params)
        comps = eng.generate(prompts, SamplingParams(max_tokens=32))
        outs[path] = [c.tokens for c in comps]
        routed = {k: sum(c.path_counts[k] for c in comps)
                  for k in ("direct", "staged", "prefill")}
        print(f"{path:9s} tokens={sum(c.n_tokens for c in comps)} "
              f"routed={routed} drains={eng.stats['drains']} "
              f"ttft_max={max(c.ttft_s for c in comps) * 1e3:.1f}ms")

    same_sd = all(np.array_equal(a, b)
                  for a, b in zip(outs["direct"], outs["staged"]))
    same_da = all(np.array_equal(a, b)
                  for a, b in zip(outs["direct"], outs["adaptive"]))
    print(f"identical tokens across write paths: staged={same_sd} "
          f"adaptive={same_da}")
    assert same_sd and same_da


if __name__ == "__main__":
    main()
