"""flash_decode_paged — the fused paged-attention read kernel.

Parity contract (DESIGN.md §7), tested at two boundaries:

* KERNEL boundary: the Pallas kernel (interpret mode on CPU) replicates
  the jnp reference's exact op ORDER and is held to fp32 ulp-level
  equality (~1e-7 abs; tolerance carries 10x margin) — swept across ring
  states (empty / partial / full / wrapped / conflict-shaped), chunk
  sizes, GQA group sizes, and page geometries, and cross-checked against
  the REAL reference core (``gather_view`` + ring concat + ``layers``
  sdpa math, which IS bitwise-equal to the packaged oracle) so the
  oracle can't drift into a strawman. Bit-identity across the two
  formulations is not achievable on this stack: XLA tiles the kernel's
  per-page [C, ps] score dots differently from the reference's
  full-width einsum, reassociating the fp32 sums.
* ENGINE boundary: fused vs reference serving produces IDENTICAL token
  streams across every paged-layout arch in the config matrix × write
  modes (direct / staged / adaptive) × chunked scheduling — ulp noise
  never flips a greedy argmax in these sweeps, and token equality is the
  contract serving actually needs.

Also here: ``core.paths.resolve_attention`` negotiation and the
``drain_ring`` automatic kernel selection (its own parity included).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.core.paths import resolve_attention
from repro.data import synthetic_requests
from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode_paged
from repro.kvcache import paged as PG
from repro.models import build_model
from repro.serve import BatchConfig, BatchedServeEngine
from repro.serve.scheduler import paged_capable

MAX_SEQ, PLEN, MAX_NEW = 32, 8, 5


def _paged_archs():
    picks = []
    for arch in sorted(ARCHS):
        cfg = get_config(arch).reduced()
        if paged_capable(build_model(cfg)):
            picks.append(arch)
    return picks


PAGED_ARCHS = _paged_archs()


# ---------------------------------------------------------------------------
# kernel vs oracle: fp32 ulp-level, swept
# ---------------------------------------------------------------------------


def _assert_ulp_close(actual, desired):
    """Kernel-boundary parity: ~1e-7 observed, 10x margin. Real kernel
    bugs (wrong page, stale mask, dropped lane) miss by >= 1e-3."""
    np.testing.assert_allclose(np.asarray(actual), np.asarray(desired),
                               atol=2e-6, rtol=1e-4)


def _rand_inputs(rng, b, c, hq, hkv, d, nb, ps, p, r):
    q = jnp.asarray(rng.randn(b, c, hq, d), jnp.float32)
    pk = jnp.asarray(rng.randn(nb, ps, hkv, d), jnp.float32)
    pv = jnp.asarray(rng.randn(nb, ps, hkv, d), jnp.float32)
    blocks = jnp.asarray(rng.randint(0, nb, (b, p)), jnp.int32)
    view_ok = jnp.asarray(rng.rand(b, c, p * ps) > 0.35)
    ring = None
    if r:
        ring = (jnp.asarray(rng.randn(b, r, hkv, d), jnp.float32),
                jnp.asarray(rng.randn(b, r, hkv, d), jnp.float32))
    return q, pk, pv, blocks, view_ok, ring


@pytest.mark.parametrize("b,c,hq,hkv,d,nb,ps,p,r", [
    (2, 1, 4, 4, 16, 8, 4, 4, 0),     # step decode, MHA, no ring
    (2, 1, 4, 2, 16, 8, 4, 4, 8),     # step decode, GQA group 2 + ring
    (1, 1, 8, 1, 32, 6, 8, 3, 4),     # MQA (group 8)
    (3, 4, 4, 2, 16, 12, 8, 4, 8),    # chunk slab C=4
    (2, 8, 4, 4, 8, 10, 4, 5, 2),     # chunk C=8, small ring
    (1, 3, 6, 3, 16, 9, 2, 6, 6),     # odd page size / group 2
])
def test_kernel_matches_oracle(b, c, hq, hkv, d, nb, ps, p, r):
    rng = np.random.RandomState(b * 100 + c * 10 + hq)
    q, pk, pv, blocks, view_ok, ring = _rand_inputs(
        rng, b, c, hq, hkv, d, nb, ps, p, r)
    if ring:
        ring_ok = jnp.asarray(rng.rand(b, r) > 0.5)
        args = (*ring, ring_ok)
    else:
        args = (None, None, None)
    out = flash_decode_paged(q, pk, pv, blocks, view_ok, *args,
                             interpret=True)
    expected = ref.flash_decode_paged_ref(q, pk, pv, blocks, view_ok, *args)
    _assert_ulp_close(out, expected)


RING_STATES = {
    "empty":    lambda b, r, rng: np.zeros((b, r), bool),
    "partial":  lambda b, r, rng: np.broadcast_to(
        np.arange(r)[None] < (r // 2), (b, r)),
    "full":     lambda b, r, rng: np.ones((b, r), bool),
    # wrapped/conflict-shaped occupancy: holes mid-ring (entries that
    # were drained out of order / lanes that skipped a column)
    "wrapped":  lambda b, r, rng: np.roll(
        np.arange(r)[None] < (r - 1), rng.randint(r), axis=1
    ) & np.ones((b, 1), bool),
    "conflict": lambda b, r, rng: rng.rand(b, r) > 0.5,
}


@pytest.mark.parametrize("state", sorted(RING_STATES))
@pytest.mark.parametrize("c", [1, 4])
def test_kernel_ring_states(state, c):
    b, hq, hkv, d, nb, ps, p, r = 3, 4, 2, 16, 12, 4, 4, 6
    rng = np.random.RandomState(abs(hash(state)) % 2**31)
    q, pk, pv, blocks, view_ok, ring = _rand_inputs(
        rng, b, c, hq, hkv, d, nb, ps, p, r)
    ring_ok = jnp.asarray(RING_STATES[state](b, r, rng))
    out = flash_decode_paged(q, pk, pv, blocks, view_ok, *ring, ring_ok,
                             interpret=True)
    expected = ref.flash_decode_paged_ref(q, pk, pv, blocks, view_ok,
                                          *ring, ring_ok)
    _assert_ulp_close(out, expected)


def test_kernel_dead_slot_and_unallocated_pages():
    """Fully-masked rows (retired slots) and clamped unallocated pages:
    the kernel walks block 0's garbage exactly like the clamped reference
    gather, so even degenerate outputs agree."""
    b, c, hq, hkv, d, nb, ps, p, r = 2, 1, 4, 2, 16, 8, 4, 4, 4
    rng = np.random.RandomState(0)
    q, pk, pv, _, _, ring = _rand_inputs(rng, b, c, hq, hkv, d, nb, ps, p, r)
    # slot 1: nothing allocated -> clamped table walks block 0, all masked
    blocks = jnp.asarray([[1, 2, 3, 4], [0, 0, 0, 0]], jnp.int32)
    view_ok = jnp.asarray(
        np.stack([np.ones((c, p * ps), bool), np.zeros((c, p * ps), bool)]))
    ring_ok = jnp.asarray([[True, False, True, False],
                           [False, False, False, False]])
    out = flash_decode_paged(q, pk, pv, blocks, view_ok, *ring, ring_ok,
                             interpret=True)
    expected = ref.flash_decode_paged_ref(q, pk, pv, blocks, view_ok,
                                          *ring, ring_ok)
    _assert_ulp_close(out, expected)


def test_oracle_matches_reference_core_bitwise():
    """The packaged oracle IS the reference path's math — gather the view
    through the page table, concat the ring lanes, repeat KV heads, and
    run the exact ``layers`` sdpa op order — and the two identical op
    sequences ARE bitwise-equal (no strawman); the kernel then sits
    within ulp of both."""
    b, c, hq, hkv, d, nb, ps, p, r = 2, 3, 4, 2, 16, 10, 4, 4, 6
    rng = np.random.RandomState(3)
    q, pk, pv, blocks, view_ok, ring = _rand_inputs(
        rng, b, c, hq, hkv, d, nb, ps, p, r)
    ring_ok = jnp.asarray(rng.rand(b, r) > 0.4)

    rows = (np.asarray(blocks)[:, :, None] * ps
            + np.arange(ps)[None, None]).reshape(b, -1)
    k = jnp.concatenate(
        [PG.gather_view(pk, jnp.asarray(rows, jnp.int32)), ring[0]], axis=1)
    v = jnp.concatenate(
        [PG.gather_view(pv, jnp.asarray(rows, jnp.int32)), ring[1]], axis=1)
    mask = jnp.concatenate(
        [view_ok, jnp.broadcast_to(ring_ok[:, None], (b, c, r))], axis=2)
    reps = hq // hkv
    kf = jnp.repeat(k, reps, axis=2)
    vf = jnp.repeat(v, reps, axis=2)
    # layers._sdpa_once op order, verbatim
    logits = jnp.einsum("bshk,bthk->bhst", q, kf).astype(jnp.float32) \
        * (d ** -0.5)
    logits = jnp.where(mask[:, None], logits, jnp.float32(-1e30))
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    core = jnp.einsum("bhst,bthk->bshk", probs, vf)

    oracle = ref.flash_decode_paged_ref(q, pk, pv, blocks, view_ok,
                                        *ring, ring_ok)
    kernel = flash_decode_paged(q, pk, pv, blocks, view_ok, *ring, ring_ok,
                                interpret=True)
    np.testing.assert_array_equal(np.asarray(core), np.asarray(oracle))
    _assert_ulp_close(kernel, core)


# ---------------------------------------------------------------------------
# model level: fused vs reference decode paths
# ---------------------------------------------------------------------------


def _paged_cache_with_ring(model, rng, n_slots=4, nb=16, ps=4, mp=8, rs=4):
    cfg = model.cfg
    cache = PG.make_paged_kv(
        cfg.n_layers, nb, ps, n_slots, mp,
        cfg.n_kv_heads or cfg.n_heads, cfg.resolved_head_dim,
        ring_size=rs)
    cache["page_table"] = jnp.asarray(
        [[0, 1, 2, 3, -1, -1, -1, -1],
         [4, 5, -1, -1, -1, -1, -1, -1],
         [6, 7, 8, -1, -1, -1, -1, -1],
         [-1] * 8], jnp.int32)
    for key in ("pages_k", "pages_v", "ring_k", "ring_v"):
        cache[key] = jnp.asarray(rng.randn(*cache[key].shape), jnp.float32)
    cache["ring_pos"] = jnp.asarray(
        [[2, -1, 5, -1], [1, -1, -1, -1], [-1] * 4, [-1] * 4], jnp.int32)
    cache["ring_fill"] = jnp.asarray(3, jnp.int32)
    return cache


@pytest.mark.parametrize("variant", ["step", "chunk"])
def test_model_fused_matches_reference(variant):
    """decode_step_paged / decode_chunk_paged under attention='fused' vs
    'reference': identical argmax tokens, allclose logits, allclose cache
    (cross-graph XLA fusion of the k/v projections carries ~1 ulp — see
    module docstring)."""
    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), MAX_SEQ)
    rng = np.random.RandomState(1)
    cache = _paged_cache_with_ring(model, rng)
    wm = jnp.asarray([True, True, True, False])
    um = jnp.asarray([True, False, True, False])
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (4, 4)), jnp.int32)
    outs = {}
    for attention in ("reference", "fused"):
        if variant == "step":
            tok = jnp.asarray([5, 9, 3, 0], jnp.int32)
            pos = jnp.asarray([10, 6, 9, 0], jnp.int32)
            outs[attention] = model.decode_step_paged(
                params, dict(cache), tok, pos, wm, unload_mask=um,
                attention=attention)
        else:
            start = jnp.asarray([10, 6, 9, 0], jnp.int32)
            nv = jnp.asarray([4, 1, 2, 0], jnp.int32)
            outs[attention] = model.decode_chunk_paged(
                params, dict(cache), toks, start, nv, wm,
                unload_mask=(nv == 1) & wm, attention=attention)
    lr, cr = outs["reference"]
    lf, cf = outs["fused"]
    np.testing.assert_array_equal(np.asarray(jnp.argmax(lr, -1)),
                                  np.asarray(jnp.argmax(lf, -1)))
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lf),
                               atol=1e-5, rtol=1e-5)
    for key in cr:
        np.testing.assert_allclose(
            np.asarray(cr[key], np.float32), np.asarray(cf[key], np.float32),
            atol=1e-5, rtol=1e-5, err_msg=key)


# ---------------------------------------------------------------------------
# engine level: token parity across the config matrix × write modes
# ---------------------------------------------------------------------------


def _serve_tokens(model, params, *, attention, write_mode="adaptive",
                  chunked=False, vocab=256):
    queue = synthetic_requests(3, [PLEN, 5] if chunked else PLEN, vocab,
                               MAX_NEW, seed=7)
    eng = BatchedServeEngine(model, params, BatchConfig(
        max_seq=MAX_SEQ, n_slots=2, segment_len=2, page_size=4,
        write_mode=write_mode, ring_size=2, hot_threshold=2,
        chunked=chunked, chunk_size=3, attention=attention,
    ), _warn=False)
    return eng.serve(queue)


@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_engine_fused_token_parity_config_matrix(arch):
    """Every paged-layout arch (the GQA/MQA/bias/rope spread of the config
    matrix) serves the SAME token streams fused and reference."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), MAX_SEQ)
    ref_out = _serve_tokens(model, params, attention="reference",
                            vocab=cfg.vocab)
    fused_out = _serve_tokens(model, params, attention="fused",
                              vocab=cfg.vocab)
    assert set(ref_out) == set(fused_out) == {0, 1, 2}
    for r in ref_out:
        np.testing.assert_array_equal(ref_out[r], fused_out[r])


@pytest.mark.parametrize("write_mode", ["direct", "staged", "adaptive"])
@pytest.mark.parametrize("chunked", [False, True])
def test_engine_fused_token_parity_write_modes(write_mode, chunked):
    """Fused vs reference across write modes (direct / staged / adaptive —
    staged keeps undrained ring lanes live at read time, exercising the
    kernel's second source, including full-ring and conflict-forced
    drains with ring_size=2) and both scheduling modes."""
    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), MAX_SEQ)
    ref_out = _serve_tokens(model, params, attention="reference",
                            write_mode=write_mode, chunked=chunked,
                            vocab=cfg.vocab)
    fused_out = _serve_tokens(model, params, attention="fused",
                              write_mode=write_mode, chunked=chunked,
                              vocab=cfg.vocab)
    for r in ref_out:
        np.testing.assert_array_equal(ref_out[r], fused_out[r])


# ---------------------------------------------------------------------------
# negotiation + drain auto-selection
# ---------------------------------------------------------------------------


def test_resolve_attention_negotiation(monkeypatch):
    monkeypatch.delenv("REPRO_ATTENTION", raising=False)
    # explicit choices pass through where legal
    assert resolve_attention("fused", layout="paged") == "fused"
    assert resolve_attention("reference", layout="paged") == "reference"
    assert resolve_attention("reference", layout="lanes") == "reference"
    # fused needs a page table to walk: loud errors, not silent fallback
    with pytest.raises(ValueError, match="paged"):
        resolve_attention("fused", layout="lanes")
    with pytest.raises(ValueError, match="paged"):
        resolve_attention("fused", layout="paged", arch_paged_capable=False)
    with pytest.raises(ValueError, match="unknown attention"):
        resolve_attention("turbo", layout="paged")
    # auto: fused where the kernel compiles natively, reference on CPU
    assert resolve_attention("auto", layout="paged", backend="tpu") == "fused"
    assert resolve_attention("auto", layout="paged", backend="cpu") \
        == "reference"
    assert resolve_attention("auto", layout="lanes", backend="tpu") \
        == "reference"
    # CI override: force the kernel through auto configs
    monkeypatch.setenv("REPRO_ATTENTION", "fused")
    assert resolve_attention("auto", layout="paged", backend="cpu") == "fused"
    monkeypatch.setenv("REPRO_ATTENTION", "reference")
    assert resolve_attention("auto", layout="paged", backend="tpu") \
        == "reference"


def test_engine_resolves_attention(monkeypatch):
    monkeypatch.delenv("REPRO_ATTENTION", raising=False)
    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), MAX_SEQ)
    eng = BatchedServeEngine(model, params,
                             BatchConfig(max_seq=MAX_SEQ), _warn=False)
    # auto on CPU -> reference (the kernel is the TPU serving path)
    assert eng.attention == "reference"
    monkeypatch.setenv("REPRO_ATTENTION", "fused")
    eng = BatchedServeEngine(model, params,
                             BatchConfig(max_seq=MAX_SEQ), _warn=False)
    assert eng.attention == "fused"
    with pytest.raises(ValueError, match="paged"):
        BatchedServeEngine(model, params, BatchConfig(
            max_seq=MAX_SEQ, kv_layout="lanes", attention="fused"),
            _warn=False)


def test_drain_kernel_auto_selection(monkeypatch):
    """Satellite: drain_ring(use_kernel=None) picks the kernel wherever the
    layout supports it without callers opting in — REPRO_DRAIN_KERNEL=1
    routes CPU CI through the interpret kernel, and the result is bitwise
    the jnp drain."""
    monkeypatch.delenv("REPRO_DRAIN_KERNEL", raising=False)
    assert PG._auto_drain_kernel() is (jax.default_backend() != "cpu")
    monkeypatch.setenv("REPRO_DRAIN_KERNEL", "1")
    assert PG._auto_drain_kernel() is True
    monkeypatch.setenv("REPRO_DRAIN_KERNEL", "0")
    assert PG._auto_drain_kernel() is False

    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    rng = np.random.RandomState(5)
    cache = _paged_cache_with_ring(model, rng)
    plain = PG.drain_ring(dict(cache), use_kernel=False)
    monkeypatch.setenv("REPRO_DRAIN_KERNEL", "1")
    auto = PG.drain_ring(dict(cache))  # auto -> interpret kernel on CPU
    for key in plain:
        np.testing.assert_array_equal(np.asarray(plain[key]),
                                      np.asarray(auto[key]), err_msg=key)
    assert int(auto["ring_fill"]) == 0
    assert (np.asarray(auto["ring_pos"]) == -1).all()
