"""Policy + decision-module tests (paper §3.2)."""
import jax.numpy as jnp

from repro.core.decision import DecisionModule, expert_hot_mask
from repro.core.monitor import ExactMonitor
from repro.core.policy import (
    AlwaysOffload,
    AlwaysUnload,
    FrequencyPolicy,
    HintPolicy,
    HysteresisPolicy,
    top_k_hot_table,
)
from repro.core.types import make_write_batch


def _batch(regions, sizes=None, hints=None):
    r = jnp.asarray(regions, jnp.int32)
    kw = {}
    if sizes is not None:
        kw["size"] = jnp.asarray(sizes, jnp.int32)
    if hints is not None:
        kw["hint"] = jnp.asarray(hints, jnp.int32)
    return make_write_batch(r, **kw)


def test_always_policies():
    b = _batch([1, 2, 3])
    assert not AlwaysOffload().decide(None, b).any()
    assert AlwaysUnload().decide(None, b).all()


def test_hint_policy_per_request_marks():
    b = _batch([5, 6, 7], hints=[1, 0, 1])
    un = HintPolicy().decide(None, b)
    # hinted (hot) -> offload (False); unhinted -> unload (True)
    assert un.tolist() == [False, True, False]


def test_hint_policy_hot_table_and_size_gate():
    hot = jnp.zeros((10,), bool).at[jnp.asarray([1, 2])].set(True)
    b = _batch([1, 3, 2, 4], sizes=[16, 16, 16, 10_000])
    un = HintPolicy(hot_regions=hot, max_unload_size=4096).decide(None, b)
    # region 1,2 hot -> offload; region 3 cold+small -> unload;
    # region 4 cold but LARGE -> stays offloaded (paper: small writes only)
    assert un.tolist() == [False, True, False, False]


def test_frequency_policy_threshold():
    mon = ExactMonitor(n_regions=16)
    st = mon.init()
    st = mon.update(st, jnp.asarray([7] * 10 + [3], jnp.int32))
    pol = FrequencyPolicy(monitor=mon, threshold=5)
    un = pol.decide(st, _batch([7, 3]))
    assert un.tolist() == [False, True]  # hot region 7 offloads, cold 3 unloads


def test_frequency_policy_relative_threshold():
    mon = ExactMonitor(n_regions=4)
    st = mon.init()
    st = mon.update(st, jnp.asarray([0] * 97 + [1, 2, 3], jnp.int32))
    pol = FrequencyPolicy(monitor=mon, rel=1.0, n_regions=4)
    un = pol.decide(st, _batch([0, 1]))
    # uniform expectation = 25; region0 (97) >= 25 offloads, region1 (1) unloads
    assert un.tolist() == [False, True]


def test_decision_module_updates_monitor_then_decides():
    mon = ExactMonitor(n_regions=8)
    dm = DecisionModule(policy=FrequencyPolicy(monitor=mon, threshold=2), monitor=mon)
    st = dm.init_state()
    # first sighting of region 5: count becomes 1 < 2 -> unload
    un, st, stats = dm(st, _batch([5]))
    assert un.tolist() == [True]
    # two more: count reaches 3 >= 2 -> offload
    un, st, stats = dm(st, _batch([5, 5]))
    assert un.tolist()[-1] == False  # noqa: E712
    assert int(stats.n_offloaded) + int(stats.n_unloaded) == 2


def test_hysteresis_policy_prefers_offload_between_bands():
    mon = ExactMonitor(n_regions=8)
    st = mon.init()
    st = mon.update(st, jnp.asarray([1] * 5, jnp.int32))  # mid-band count=5
    pol = HysteresisPolicy(monitor=mon, lo=2, hi=8)
    un = pol.decide(st, _batch([1]))
    assert not bool(un[0])  # between lo/hi -> safe default = offload


def test_hysteresis_carries_last_decision_through_midband():
    """The documented behaviour: unload below lo, offload at/above hi, and
    IN BETWEEN keep the region's last decision (both directions)."""
    mon = ExactMonitor(n_regions=8)
    pol = HysteresisPolicy(monitor=mon, lo=2, hi=6)
    st = pol.init_state()
    # count 1 (< lo): unload, and the decision is remembered
    un, st = pol.route(st, _batch([3]))
    assert un.tolist() == [True]
    # counts 2..5 (mid-band): stays UNLOADED — no flapping at lo
    for expect_count in (2, 3, 4, 5):
        un, st = pol.route(st, _batch([3]))
        assert int(mon.query(st.mon, jnp.asarray([3]))[0]) == expect_count
        assert un.tolist() == [True], expect_count
    # count 6 (>= hi): flips to offload
    un, st = pol.route(st, _batch([3]))
    assert un.tolist() == [False]
    # back in the mid-band on a LATER batch: stays OFFLOADED now
    un, st = pol.route(st, _batch([3]))
    assert un.tolist() == [False]


def test_hysteresis_buckets_regions_beyond_table():
    """Region ids >= n_regions (CMS universes) must keep hysteresis via
    deterministic modulo bucketing — not silently drop the memory write."""
    from repro.core.monitor import CMSMonitor

    pol = HysteresisPolicy(monitor=CMSMonitor(depth=2, log2_width=6),
                           lo=2, hi=5, n_regions=8)
    st = pol.init_state()
    un, st = pol.route(st, _batch([100]))   # count 1 < lo -> unload
    assert un.tolist() == [True]
    assert bool(st.last_unload[100 % 8])    # memory actually recorded
    for _ in range(3):                      # counts 2..4: mid-band
        un, st = pol.route(st, _batch([100]))
        assert un.tolist() == [True]        # keeps the last decision
    un, st = pol.route(st, _batch([100]))   # count 5 >= hi -> offload
    assert un.tolist() == [False]


def test_hysteresis_under_decision_module_and_jit():
    import jax

    mon = ExactMonitor(n_regions=4)
    dm = DecisionModule(policy=HysteresisPolicy(monitor=mon, lo=2, hi=6))
    st = dm.init_state()

    @jax.jit
    def step(state, batch):
        return dm(state, batch)

    un, st, stats = step(st, _batch([0, 1]))
    assert un.tolist() == [True, True]  # fresh regions: count 1 < lo
    assert int(stats.n_unloaded) == 2
    for _ in range(4):  # push region 0 to count >= hi
        un, st, _ = step(st, _batch([0, 0]))
    assert not bool(un[0])


def test_top_k_hot_table():
    counts = jnp.asarray([5, 1, 9, 3], jnp.int32)
    hot = top_k_hot_table(counts, 2)
    assert hot.tolist() == [True, False, True, False]


def test_expert_hot_mask():
    load = jnp.asarray([100, 2, 50, 1, 75, 3, 2, 1], jnp.int32)
    hot = expert_hot_mask(load, 3)
    assert hot.tolist() == [True, False, True, False, True, False, False, False]
