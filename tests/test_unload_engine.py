"""uMTT + staging ring + RemoteWriteEngine tests (paper §3.1)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import umtt as U
from repro.core import unload as UL
from repro.core.decision import DecisionModule
from repro.core.monitor import ExactMonitor
from repro.core.policy import AlwaysOffload, AlwaysUnload, FrequencyPolicy
from repro.core.staged_write import RemoteWriteEngine
from repro.core.types import make_write_batch


# ---------------------------------------------------------------------------
# uMTT
# ---------------------------------------------------------------------------


def test_umtt_register_validate_deregister():
    t = U.make_umtt(8)
    t = U.register(t, base=0, n_regions=10, stag=42)
    ok = U.validate(t, jnp.asarray([0, 9, 10], jnp.int32),
                    jnp.asarray([42, 42, 42], jnp.int32))
    assert ok.tolist() == [True, True, False]  # range check
    ok = U.validate(t, jnp.asarray([5], jnp.int32), jnp.asarray([7], jnp.int32))
    assert ok.tolist() == [False]  # wrong stag
    t = U.deregister(t, stag=42)
    ok = U.validate(t, jnp.asarray([5], jnp.int32), jnp.asarray([42], jnp.int32))
    assert ok.tolist() == [False]  # removed at dereg (paper §3.1)


def test_umtt_permissions():
    t = U.make_umtt(4)
    t = U.register(t, 0, 4, stag=1, perm=U.PERM_READ)  # read-only region
    ok = U.validate(t, jnp.asarray([1], jnp.int32), jnp.asarray([1], jnp.int32),
                    need_perm=U.PERM_WRITE)
    assert ok.tolist() == [False]


def test_umtt_multiple_registrations():
    t = U.make_umtt(8)
    t = U.register(t, 0, 4, stag=1)
    t = U.register(t, 100, 4, stag=2)
    ok = U.validate(t, jnp.asarray([2, 102, 102], jnp.int32),
                    jnp.asarray([1, 2, 1], jnp.int32))
    assert ok.tolist() == [True, True, False]


# ---------------------------------------------------------------------------
# staging ring
# ---------------------------------------------------------------------------


def _full_table(n_regions):
    t = U.make_umtt(8)
    return U.register(t, 0, n_regions, stag=7)


def test_ring_append_sequential_slots():
    ring = UL.make_ring(8, 4)
    pay = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
    mask = jnp.asarray([True, False, True])
    ring, slot = UL.append(
        ring, pay, jnp.asarray([1, 2, 3], jnp.int32),
        jnp.zeros(3, jnp.int32), jnp.full((3,), 4, jnp.int32),
        jnp.full((3,), 7, jnp.int32), mask,
    )
    # staged entries take consecutive slots; skipped one gets none
    assert slot.tolist() == [0, -1, 1] or slot.tolist() == [0, 8, 1]
    assert int(ring.head) == 2
    assert ring.live.tolist()[:2] == [True, True]


def test_drain_respects_umtt_and_copies():
    table = _full_table(4)
    ring = UL.make_ring(4, 4)
    pay = jnp.ones((2, 4), jnp.float32)
    ring, _ = UL.append(
        ring, pay, jnp.asarray([2, 3], jnp.int32), jnp.zeros(2, jnp.int32),
        jnp.full((2,), 4, jnp.int32),
        jnp.asarray([7, 99], jnp.int32),  # second has a BAD stag
        jnp.ones(2, bool),
    )
    mem = jnp.zeros((4, 4))
    ring, mem, rejected = UL.drain(ring, mem, table)
    assert int(rejected) == 1
    assert bool(jnp.all(mem[2] == 1.0))
    assert bool(jnp.all(mem[3] == 0.0))  # rejected write never lands
    assert not bool(ring.live.any())


def test_need_drain_watermark():
    ring = UL.make_ring(4, 2)
    pay = jnp.zeros((3, 2))
    ring, _ = UL.append(ring, pay, jnp.zeros(3, jnp.int32), jnp.zeros(3, jnp.int32),
                        jnp.full((3,), 2, jnp.int32), jnp.zeros(3, jnp.int32),
                        jnp.ones(3, bool))
    assert bool(UL.need_drain(ring, 2))
    assert not bool(UL.need_drain(ring, 1))


# ---------------------------------------------------------------------------
# RemoteWriteEngine: parity / ordering / security / telemetry
# ---------------------------------------------------------------------------


def _engine(policy, monitor=None, ring=32, width=8):
    return RemoteWriteEngine(
        decision=DecisionModule(policy=policy, monitor=monitor),
        ring_capacity=ring, width=width,
    )


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("mode", ["offload", "unload", "adaptive"])
def test_engine_parity_against_python_oracle(seed, mode):
    """PROPERTY: after flush, engine memory == last-write-wins oracle,
    for any path mix (the ordering-parity guarantee, beyond the paper)."""
    R, W = 32, 8
    table = _full_table(R)
    mon = ExactMonitor(n_regions=R)
    policy = {
        "offload": AlwaysOffload(),
        "unload": AlwaysUnload(),
        "adaptive": FrequencyPolicy(monitor=mon, threshold=3),
    }[mode]
    eng = _engine(policy, mon if mode == "adaptive" else None, ring=16, width=W)
    state = eng.init_state(table)
    mem = jnp.zeros((R, W))
    rng = np.random.RandomState(seed)
    ref = np.zeros((R, W))
    for _ in range(12):
        regions = rng.choice([0, 0, 1, *range(4, 16)], size=8).astype(np.int32)
        payload = rng.randn(8, W).astype(np.float32)
        batch = make_write_batch(jnp.asarray(regions),
                                 size=jnp.full((8,), W, jnp.int32))
        state, mem = eng.write(state, mem, batch, jnp.asarray(payload),
                               jnp.full((8,), 7, jnp.int32))
        for i in range(8):
            ref[regions[i]] = payload[i]
    state, mem = eng.flush(state, mem)
    np.testing.assert_allclose(np.asarray(mem), ref)


def test_engine_rejects_bad_stag_on_unload_path():
    table = _full_table(8)
    eng = _engine(AlwaysUnload(), ring=8, width=4)
    st = eng.init_state(table)
    batch = make_write_batch(jnp.asarray([3], jnp.int32),
                             size=jnp.asarray([4], jnp.int32))
    st, mem = eng.write(st, jnp.zeros((8, 4)), batch, jnp.ones((1, 4)),
                        jnp.asarray([99], jnp.int32))
    st, mem = eng.flush(st, mem)
    assert int(st.n_rejected) == 1
    assert bool(jnp.all(mem == 0))


def test_engine_telemetry_counts():
    table = _full_table(8)
    mon = ExactMonitor(n_regions=8)
    eng = _engine(FrequencyPolicy(monitor=mon, threshold=100), mon, width=4)
    st = eng.init_state(table)
    batch = make_write_batch(jnp.asarray([0, 1, 2], jnp.int32),
                             size=jnp.full((3,), 4, jnp.int32))
    st, _ = eng.write(st, jnp.zeros((8, 4)), batch, jnp.zeros((3, 4)),
                      jnp.full((3,), 7, jnp.int32))
    assert int(st.n_unloaded) == 3  # everything cold under huge threshold
    assert int(st.n_offloaded) == 0


# ---------------------------------------------------------------------------
# path parity (PROPERTY): unload path == write_direct oracle, bit-identical
# ---------------------------------------------------------------------------


def _py_oracle(shape, writes):
    """Sequential last-write-wins reference, skipping invalid writes."""
    ref = np.zeros(shape, np.float32)
    for region, offset, size, ok, payload in writes:
        if ok:
            ref[region, offset:offset + size] = payload[:size]
    return ref


@pytest.mark.parametrize("seed", range(6))
def test_unload_path_bit_identical_to_direct_oracle(seed):
    """PROPERTY (DESIGN.md §1.3): ``write`` + ``flush`` under AlwaysUnload
    is BIT-identical to the ``write_direct`` oracle, exercising ring-wrap
    (capacity 8 << total writes), conflict-forced drains (destinations
    repeat across batches), partial sizes, sub-region offsets, and uMTT
    rejections (bad stags and unregistered regions never land).

    Destinations are unique (region, offset) pairs WITHIN a batch and
    lane-disjoint across offsets — the only intra-batch overlap the engine
    contracts to order (``_last_wins`` suppresses exact duplicate keys;
    overlapping-but-unequal destinations are the caller's race, as in RDMA).
    """
    R, W = 16, 8
    table = U.make_umtt(8)
    table = U.register(table, base=0, n_regions=12, stag=7)  # 12..15 invalid
    eng = _engine(AlwaysUnload(), ring=8, width=W)
    state = eng.init_state(table)
    mem = jnp.zeros((R, W))
    rng = np.random.RandomState(seed)
    writes = []
    n = 6
    for _ in range(10):
        # unique destination keys this batch; lanes [0, 4) vs [4, 8) disjoint
        pairs = rng.permutation(R * 2)[:n]
        regions = (pairs // 2).astype(np.int32)
        offsets = ((pairs % 2) * 4).astype(np.int32)
        sizes = rng.randint(1, 5, size=n).astype(np.int32)
        stags = np.where(rng.rand(n) < 0.8, 7, 99).astype(np.int32)
        payload = rng.randn(n, W).astype(np.float32)
        batch = make_write_batch(jnp.asarray(regions),
                                 offset=jnp.asarray(offsets),
                                 size=jnp.asarray(sizes))
        state, mem = eng.write(state, mem, batch, jnp.asarray(payload),
                               jnp.asarray(stags))
        for i in range(n):
            ok = regions[i] < 12 and stags[i] == 7
            writes.append((regions[i], offsets[i], sizes[i], ok, payload[i]))
    state, mem = eng.flush(state, mem)
    ref = _py_oracle((R, W), writes)
    np.testing.assert_array_equal(np.asarray(mem), ref)
    assert int(state.n_rejected) == sum(1 for w in writes if not w[3])


@pytest.mark.parametrize("seed", range(4))
def test_adaptive_mix_bit_identical_to_direct_oracle(seed):
    """Same property under a path MIX (FrequencyPolicy): callers can never
    observe which path a write took."""
    R, W = 16, 4
    table = _full_table(R)
    mon = ExactMonitor(n_regions=R)
    eng = _engine(FrequencyPolicy(monitor=mon, threshold=4), mon,
                  ring=8, width=W)
    state = eng.init_state(table)
    mem = jnp.zeros((R, W))
    rng = np.random.RandomState(seed)
    writes = []
    for _ in range(10):
        # skew toward low regions (hot under the frequency policy) while
        # keeping destination keys unique within the batch
        regions = rng.permutation(np.concatenate(
            [np.arange(4), 4 + rng.permutation(R - 4)[:4]]
        ))[:5].astype(np.int32)
        sizes = rng.randint(1, W + 1, size=5).astype(np.int32)
        payload = rng.randn(5, W).astype(np.float32)
        batch = make_write_batch(jnp.asarray(regions),
                                 size=jnp.asarray(sizes))
        state, mem = eng.write(state, mem, batch, jnp.asarray(payload),
                               jnp.full((5,), 7, jnp.int32))
        for i in range(5):
            writes.append((regions[i], 0, sizes[i], True, payload[i]))
    state, mem = eng.flush(state, mem)
    np.testing.assert_array_equal(np.asarray(mem), _py_oracle((R, W), writes))


def test_scatter_rows_kernel_interpret_matches_jnp_drain():
    """The staged_scatter Pallas kernel (interpret mode) and the jnp drain
    are the same function — through the unified ``ring.scatter_rows``
    dispatcher, the single place the kernel is invoked from."""
    from repro.core import ring as R

    rng = np.random.RandomState(0)
    dest = jnp.asarray(rng.randn(32, 256), jnp.float32)
    staging = jnp.asarray(rng.randn(8, 256), jnp.float32)
    rows = jnp.asarray(rng.permutation(32)[:8], jnp.int32)
    valid = jnp.asarray([True, True, False, True, False, True, True, False])
    a = R.scatter_rows(dest, staging, rows, valid,
                       use_kernel=True, interpret=True)
    b = R.scatter_rows(dest, staging, rows, valid, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_partial_write_sizes():
    """Writes smaller than the region width only touch their bytes."""
    table = _full_table(4)
    eng = _engine(AlwaysOffload(), width=8)
    st = eng.init_state(table)
    mem = jnp.full((4, 8), -1.0)
    batch = make_write_batch(jnp.asarray([1], jnp.int32),
                             size=jnp.asarray([3], jnp.int32))
    st, mem = eng.write(st, mem, batch, jnp.ones((1, 8)),
                        jnp.asarray([7], jnp.int32))
    assert mem[1].tolist() == [1, 1, 1, -1, -1, -1, -1, -1]
