"""Device-resident serving decode: the jitted lax.scan loop must match the
per-step Python reference loop (tokens AND telemetry) in every write mode,
and must not host-sync per step."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeConfig, ServeEngine


def _setup(mode, greedy=True, hot_threshold=6):
    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), 64)
    prompt = jax.random.randint(jax.random.key(1), (3, 12), 0, cfg.vocab)
    mk = lambda: ServeEngine(model, params, ServeConfig(  # noqa: E731
        max_seq=64, write_mode=mode, ring_size=4, page_size=8,
        hot_threshold=hot_threshold, greedy=greedy,
    ))
    return mk, prompt


@pytest.mark.parametrize("mode", ["direct", "staged", "adaptive"])
def test_scan_decode_matches_reference_loop(mode):
    """Tokens and device-accumulated stats == the seed's Python loop."""
    mk, prompt = _setup(mode)
    eng_scan, eng_ref = mk(), mk()
    toks_scan = eng_scan.generate(prompt, 10)
    toks_ref = eng_ref.generate(prompt, 10, reference=True)
    np.testing.assert_array_equal(np.asarray(toks_scan), np.asarray(toks_ref))
    assert eng_scan.stats == eng_ref.stats
    if mode == "staged":
        assert eng_scan.stats["staged_writes"] > 0
        assert eng_scan.stats["drains"] > 0  # ring_size 4 < 9 decode steps


def test_scan_decode_sampled_matches_reference_loop():
    """Sampled decode: the scan splits the PRNG key exactly like the loop."""
    mk, prompt = _setup("staged", greedy=False)
    key = jax.random.key(7)
    toks_scan = mk().generate(prompt, 8, sample_key=key)
    toks_ref = mk().generate(prompt, 8, sample_key=key, reference=True)
    np.testing.assert_array_equal(np.asarray(toks_scan), np.asarray(toks_ref))


def test_decode_loop_is_jit_cached_and_host_sync_free():
    """The whole decode loop compiles ONCE per (n_steps, sampling mode) and
    runs without per-step host transfers: a second generate() call reuses
    the cached compiled function, and the traced step never leaves the
    device (trace-counting via a jax callback-free probe: we assert the
    jitted callable count, not timings)."""
    mk, prompt = _setup("adaptive")
    eng = mk()
    eng.generate(prompt, 6)
    assert len(eng._decode_fns) == 1
    eng.generate(prompt, 6)  # same shape -> no new entry
    assert len(eng._decode_fns) == 1
    eng.generate(prompt, 9)  # new n_steps -> one more compiled loop
    assert len(eng._decode_fns) == 2
    # stats accumulated across calls (single readback per call)
    total = eng.stats["direct_writes"] + eng.stats["staged_writes"]
    assert total == 3 * (5 + 5 + 8)  # B=3, n_steps-1 decode steps per call


def test_adaptive_mode_routes_a_mix_through_decision_module():
    """With a threshold above the per-step page-hit rate, fresh pages stage
    first and flip to direct once hot — both counters advance, and the
    routing state is the DecisionModule's (no private serve-side policy)."""
    from repro.core.decision import DecisionModule

    mk, prompt = _setup("adaptive", hot_threshold=10)
    eng = mk()
    assert isinstance(eng.decision, DecisionModule)
    eng.generate(prompt, 12)
    assert eng.stats["staged_writes"] > 0
    assert eng.stats["direct_writes"] > 0
