"""CMS Pallas kernel parity: interpret-mode update/query vs the jnp oracle
(``kernels/ref.py``), vs the ``CMSMonitor`` (the state the serve engines
actually carry), and vs ``ExactMonitor`` where the sketch is collision-free
by construction. Plus the colliding-ids property: the kernel's one-hot
histogram accumulates EVERY duplicate (a serialized scatter-add would too —
a racy one would lose increments)."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import seeds
from repro.core.monitor import CMSMonitor, ExactMonitor
from repro.kernels import ref
from repro.kernels.cms import cms_query, cms_update


def _ids(seed, n, universe):
    return jnp.asarray(
        np.random.RandomState(seed).randint(0, universe, size=n), jnp.int32)


@pytest.mark.parametrize("n", [64, 256, 300, 1000])  # incl. ghost-pad sizes
def test_kernel_matches_oracle_and_monitor(n):
    """Kernel (interpret) == jnp oracle == CMSMonitor.update/query — the
    monitor is what the decision module carries, so kernel drift against it
    would silently skew routing."""
    for seed in seeds(3):
        counts = jnp.zeros((4, 1 << 10), jnp.int32)
        ids = _ids(seed, n, 1 << 20)
        up_k = cms_update(counts, ids, interpret=True)
        up_r = ref.cms_update_ref(counts, ids)
        np.testing.assert_array_equal(np.asarray(up_k), np.asarray(up_r))
        mon = CMSMonitor(depth=4, log2_width=10)
        st = mon.update(mon.init(), ids)
        np.testing.assert_array_equal(np.asarray(up_k), np.asarray(st.counts))
        q_k = cms_query(up_k, ids, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(q_k), np.asarray(ref.cms_query_ref(up_r, ids)))
        np.testing.assert_array_equal(
            np.asarray(q_k), np.asarray(mon.query(st, ids)))


def test_sketch_equals_exact_counts_on_sparse_universe():
    """With a tiny id universe and a wide sketch, collisions are absent in
    at least one row — the count-min estimate IS the exact count."""
    for seed in seeds(3):
        ids = _ids(seed, 512, 16)
        exact = ExactMonitor(n_regions=16)
        est_exact = exact.query(exact.update(exact.init(), ids),
                                jnp.arange(16, dtype=jnp.int32))
        counts = cms_update(jnp.zeros((4, 1 << 12), jnp.int32), ids,
                            interpret=True)
        est_cms = cms_query(counts, jnp.arange(16, dtype=jnp.int32),
                            interpret=True)
        np.testing.assert_array_equal(np.asarray(est_cms),
                                      np.asarray(est_exact))


def test_cms_never_undercounts():
    """Count-min admissibility: estimate >= true frequency, always."""
    for seed in seeds(3):
        ids = _ids(seed, 1024, 1 << 16)
        counts = cms_update(jnp.zeros((2, 1 << 6), jnp.int32), ids,
                            interpret=True)  # narrow -> heavy collisions
        est = np.asarray(cms_query(counts, ids, interpret=True))
        true = np.asarray(
            ExactMonitor(n_regions=1 << 16).update(
                ExactMonitor(n_regions=1 << 16).init(), ids
            ).counts)[np.asarray(ids)]
        assert (est >= true).all()


def test_colliding_ids_histogram_is_collision_safe():
    """DUPLICATE ids inside one kernel block must each contribute: the
    one-hot histogram reduction adds k for k copies, exactly like the
    sequential oracle. A TPU scatter-add that dropped colliding lanes
    would fail this."""
    # all ids identical — the worst-case intra-block collision
    ids = jnp.full((256,), 12345, jnp.int32)
    counts = cms_update(jnp.zeros((4, 1 << 10), jnp.int32), ids,
                        interpret=True)
    assert int(cms_query(counts, ids[:1], interpret=True)[0]) == 256
    np.testing.assert_array_equal(
        np.asarray(counts), np.asarray(
            ref.cms_update_ref(jnp.zeros((4, 1 << 10), jnp.int32), ids)))
    # and distinct ids that collide in a HASH BUCKET of a narrow row must
    # stack there (found by brute force against the real hash)
    log2w = 4
    h = np.asarray(ref.cms_hash(jnp.arange(2048, dtype=jnp.int32), 0, log2w))
    bucket_ids = np.flatnonzero(h == h[0])[:8]
    assert len(bucket_ids) == 8
    counts = cms_update(jnp.zeros((1, 1 << log2w), jnp.int32),
                        jnp.asarray(bucket_ids, jnp.int32), interpret=True)
    assert int(counts[0, h[0]]) == 8


def test_masked_update_skips_masked_ids():
    """The serve scheduler's inactive-slot mask: masked ids add nothing
    (counters or totals) in both monitors."""
    ids = jnp.asarray([3, 3, 9], jnp.int32)
    mask = jnp.asarray([True, False, True])
    ex = ExactMonitor(n_regions=16)
    st = ex.update(ex.init(), ids, mask=mask)
    assert st.counts[3] == 1 and st.counts[9] == 1 and int(st.total) == 2
    cm = CMSMonitor(depth=4, log2_width=8)
    st = cm.update(cm.init(), ids, mask=mask)
    assert cm.query(st, ids).tolist() == [1, 1, 1]
    assert int(st.total) == 2
