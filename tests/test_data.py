"""Data pipeline tests: determinism, host sharding, restart, memmap,
request-queue ordering."""
import numpy as np

from repro.data import (
    DataConfig,
    MemmapSource,
    Pipeline,
    RequestQueue,
    SyntheticSource,
    synthetic_requests,
)


def test_request_queue_pop_at_preserves_relative_order():
    q = RequestQueue()
    for _ in range(5):
        q.submit(np.arange(4, dtype=np.int32), 2)
    assert q.pop_at(2).req_id == 2          # skip-ahead admission
    assert [q.at(i).req_id for i in range(len(q))] == [0, 1, 3, 4]
    assert q.pop_at(0).req_id == 0          # head pop still works
    assert q.pop().req_id == 1
    assert [q.at(i).req_id for i in range(len(q))] == [3, 4]


def test_synthetic_requests_mixed_prompt_lengths():
    q = synthetic_requests(5, [12, 4], vocab=97, max_new=3, seed=1)
    lens = [q.at(i).prompt_len for i in range(len(q))]
    assert lens == [12, 4, 12, 4, 12]
    # request i's prompt is a function of (seed, i) alone: the same
    # request appears bit-identically in a uniform-length stream
    q_uniform = synthetic_requests(5, 12, vocab=97, max_new=3, seed=1)
    np.testing.assert_array_equal(q.at(0).prompt, q_uniform.at(0).prompt)
    np.testing.assert_array_equal(q.at(2).prompt, q_uniform.at(2).prompt)


def test_synthetic_deterministic_per_step():
    cfg = DataConfig(seq_len=16, global_batch=4, vocab=100)
    s = SyntheticSource(cfg)
    a, b = s.batch_at(3), s.batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = s.batch_at(4)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_shifted_by_one():
    cfg = DataConfig(seq_len=16, global_batch=2, vocab=100)
    b = SyntheticSource(cfg).batch_at(0)
    # labels are the next-token stream: token[i+1] == label[i]
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_sharding_partitions_global_batch():
    full = SyntheticSource(DataConfig(seq_len=8, global_batch=4, vocab=50))
    h0 = SyntheticSource(DataConfig(seq_len=8, global_batch=4, vocab=50,
                                    num_hosts=2, host_index=0))
    h1 = SyntheticSource(DataConfig(seq_len=8, global_batch=4, vocab=50,
                                    num_hosts=2, host_index=1))
    f, a, b = full.batch_at(5), h0.batch_at(5), h1.batch_at(5)
    np.testing.assert_array_equal(np.concatenate([a["tokens"], b["tokens"]]),
                                  f["tokens"])


def test_pipeline_prefetch_and_skip(tmp_path):
    cfg = DataConfig(seq_len=8, global_batch=2, vocab=50)
    src = SyntheticSource(cfg)
    pipe = Pipeline(src).start()
    b0 = next(pipe)
    next(pipe)
    pipe.skip_to(10)
    b10 = next(pipe)
    pipe.stop()
    np.testing.assert_array_equal(b10["tokens"], src.batch_at(10)["tokens"])
    np.testing.assert_array_equal(b0["tokens"], src.batch_at(0)["tokens"])


def test_memmap_source(tmp_path):
    path = str(tmp_path / "tokens.bin")
    data = np.arange(1000, dtype=np.int32) % 77
    data.tofile(path)
    cfg = DataConfig(seq_len=10, global_batch=2, vocab=77)
    src = MemmapSource(cfg, path)
    b = src.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][0], data[:10])
    np.testing.assert_array_equal(b["labels"][0], data[1:11])
    # restartability: same step -> same batch
    np.testing.assert_array_equal(src.batch_at(4)["tokens"],
                                  src.batch_at(4)["tokens"])
