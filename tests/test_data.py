"""Data pipeline tests: determinism, host sharding, restart, memmap,
request-queue ordering (incl. SamplingParams carriage through
synthetic_requests and skip-ahead admission)."""
import warnings

import numpy as np
import pytest

from repro.data import (
    DataConfig,
    MemmapSource,
    Pipeline,
    RequestQueue,
    SamplingParams,
    SyntheticSource,
    synthetic_requests,
)


def test_request_queue_pop_at_preserves_relative_order():
    q = RequestQueue()
    for _ in range(5):
        q.submit(np.arange(4, dtype=np.int32), 2)
    assert q.pop_at(2).req_id == 2          # skip-ahead admission
    assert [q.at(i).req_id for i in range(len(q))] == [0, 1, 3, 4]
    assert q.pop_at(0).req_id == 0          # head pop still works
    assert q.pop().req_id == 1
    assert [q.at(i).req_id for i in range(len(q))] == [3, 4]


def test_queue_carries_sampling_params():
    """submit/at/pop_at carry SamplingParams untouched; the legacy
    max_new argument overrides max_tokens; params-less submits still
    need a budget."""
    q = RequestQueue()
    p = SamplingParams(temperature=0.7, top_k=5, max_tokens=9, seed=3)
    q.submit(np.arange(4, dtype=np.int32), params=p)
    q.submit(np.arange(4, dtype=np.int32), 3, params=p)   # max_new wins
    q.submit(np.arange(4, dtype=np.int32), 3)
    assert q.at(0).params == p and q.at(0).max_new == 9
    assert q.at(1).params.max_tokens == 3
    assert q.at(1).params.temperature == 0.7              # rest untouched
    assert q.at(2).params.max_tokens == 3
    assert q.at(2).params.temperature is None             # engine default
    assert q.pop_at(1).params.max_tokens == 3             # skip-ahead keeps params
    assert q.pop().params == p
    with pytest.raises(ValueError):
        q.submit(np.arange(4, dtype=np.int32))            # no budget at all


def test_synthetic_requests_carry_params_cycled():
    plist = [SamplingParams(max_tokens=4, temperature=0.0, seed=1),
             SamplingParams(max_tokens=6, temperature=1.1, seed=2)]
    q = synthetic_requests(5, 8, vocab=97, max_new=3, seed=1, params=plist)
    got = [q.at(i).params for i in range(len(q))]
    assert got == [plist[0], plist[1], plist[0], plist[1], plist[0]]
    # prompts are independent of the params mix
    q_plain = synthetic_requests(5, 8, vocab=97, max_new=3, seed=1)
    for i in range(5):
        np.testing.assert_array_equal(q.at(i).prompt, q_plain.at(i).prompt)
        assert q_plain.at(i).params.max_tokens == 3


def test_skip_ahead_admission_keeps_same_size_fifo_with_params():
    """Regression: requests of EQUAL footprint but different
    SamplingParams must admit strictly FIFO — the skip-ahead scan may
    reorder only when a head request genuinely does not fit."""
    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve import BatchConfig, BatchedServeEngine

    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    mparams = model.init(jax.random.key(0), 32)
    plist = [SamplingParams(max_tokens=4, temperature=0.0),
             SamplingParams(max_tokens=4, temperature=1.2, seed=9),
             SamplingParams(max_tokens=4, top_k=3, seed=1)]
    queue = synthetic_requests(6, 8, cfg.vocab, 4, seed=2, params=plist)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        eng = BatchedServeEngine(model, mparams, BatchConfig(
            max_seq=32, n_slots=2, segment_len=2, page_size=4,
            n_blocks=6))  # room for exactly the two live slots
    admitted = []
    for _ in range(200):
        eng.retire_done()
        eng.admit(queue)
        for s in range(eng.cfg.n_slots):
            rid = eng._slot_req[s]
            if eng._occupied[s] and rid not in admitted:
                admitted.append(rid)
        if not any(eng._occupied):
            break
        eng.run_segment()
    # same-size stream: admission order IS submission order
    assert sorted(admitted) == admitted == list(range(6))
    assert all(len(eng.outputs[r]) == 4 for r in range(6))


def test_synthetic_requests_mixed_prompt_lengths():
    q = synthetic_requests(5, [12, 4], vocab=97, max_new=3, seed=1)
    lens = [q.at(i).prompt_len for i in range(len(q))]
    assert lens == [12, 4, 12, 4, 12]
    # request i's prompt is a function of (seed, i) alone: the same
    # request appears bit-identically in a uniform-length stream
    q_uniform = synthetic_requests(5, 12, vocab=97, max_new=3, seed=1)
    np.testing.assert_array_equal(q.at(0).prompt, q_uniform.at(0).prompt)
    np.testing.assert_array_equal(q.at(2).prompt, q_uniform.at(2).prompt)


def test_synthetic_deterministic_per_step():
    cfg = DataConfig(seq_len=16, global_batch=4, vocab=100)
    s = SyntheticSource(cfg)
    a, b = s.batch_at(3), s.batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = s.batch_at(4)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_shifted_by_one():
    cfg = DataConfig(seq_len=16, global_batch=2, vocab=100)
    b = SyntheticSource(cfg).batch_at(0)
    # labels are the next-token stream: token[i+1] == label[i]
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_sharding_partitions_global_batch():
    full = SyntheticSource(DataConfig(seq_len=8, global_batch=4, vocab=50))
    h0 = SyntheticSource(DataConfig(seq_len=8, global_batch=4, vocab=50,
                                    num_hosts=2, host_index=0))
    h1 = SyntheticSource(DataConfig(seq_len=8, global_batch=4, vocab=50,
                                    num_hosts=2, host_index=1))
    f, a, b = full.batch_at(5), h0.batch_at(5), h1.batch_at(5)
    np.testing.assert_array_equal(np.concatenate([a["tokens"], b["tokens"]]),
                                  f["tokens"])


def test_pipeline_prefetch_and_skip(tmp_path):
    cfg = DataConfig(seq_len=8, global_batch=2, vocab=50)
    src = SyntheticSource(cfg)
    pipe = Pipeline(src).start()
    b0 = next(pipe)
    next(pipe)
    pipe.skip_to(10)
    b10 = next(pipe)
    pipe.stop()
    np.testing.assert_array_equal(b10["tokens"], src.batch_at(10)["tokens"])
    np.testing.assert_array_equal(b0["tokens"], src.batch_at(0)["tokens"])


def test_memmap_source(tmp_path):
    path = str(tmp_path / "tokens.bin")
    data = np.arange(1000, dtype=np.int32) % 77
    data.tofile(path)
    cfg = DataConfig(seq_len=10, global_batch=2, vocab=77)
    src = MemmapSource(cfg, path)
    b = src.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][0], data[:10])
    np.testing.assert_array_equal(b["labels"][0], data[1:11])
    # restartability: same step -> same batch
    np.testing.assert_array_equal(src.batch_at(4)["tokens"],
                                  src.batch_at(4)["tokens"])
