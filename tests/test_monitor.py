"""Monitor unit + property tests (paper §3.2)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.monitor import CMSMonitor, ExactMonitor, calibrate_threshold


def test_exact_counts_match_histogram():
    mon = ExactMonitor(n_regions=64)
    st = mon.init()
    rng = np.random.RandomState(0)
    ids = rng.randint(0, 64, size=500).astype(np.int32)
    st = mon.update(st, jnp.asarray(ids))
    expected = np.bincount(ids, minlength=64)
    np.testing.assert_array_equal(np.asarray(st.counts), expected)
    assert int(st.total) == 500


def test_exact_query():
    mon = ExactMonitor(n_regions=8)
    st = mon.init()
    st = mon.update(st, jnp.asarray([3, 3, 3, 1], jnp.int32))
    q = mon.query(st, jnp.asarray([3, 1, 0], jnp.int32))
    assert q.tolist() == [3, 1, 0]


@pytest.mark.parametrize("seed", range(5))
def test_cms_never_underestimates(seed):
    """Property: CMS estimates >= exact counts (one-sided error)."""
    mon = CMSMonitor(depth=4, log2_width=10)
    st = mon.init()
    rng = np.random.RandomState(seed)
    ids = rng.randint(0, 10**6, size=400).astype(np.int32)
    st = mon.update(st, jnp.asarray(ids))
    uniq, counts = np.unique(ids, return_counts=True)
    est = np.asarray(mon.query(st, jnp.asarray(uniq)))
    assert np.all(est >= counts)


def test_cms_reasonably_tight():
    mon = CMSMonitor(depth=4, log2_width=12)
    st = mon.init()
    rng = np.random.RandomState(1)
    ids = rng.randint(0, 1000, size=2000).astype(np.int32)
    st = mon.update(st, jnp.asarray(ids))
    uniq, counts = np.unique(ids, return_counts=True)
    est = np.asarray(mon.query(st, jnp.asarray(uniq)))
    # with width >> distinct ids, overestimation should be tiny
    assert np.mean(est - counts) < 1.0


def test_calibrate_threshold_top_k():
    counts = jnp.asarray([10, 1, 8, 3, 7, 2, 9, 0], jnp.int32)
    thr = calibrate_threshold(counts, offload_top_k=3)
    # top-3 are 10, 9, 8 -> threshold 8 keeps exactly those at/above it
    assert int(thr) == 8
    assert int(jnp.sum(counts >= thr)) == 3


def test_decay_halves_counters():
    mon = ExactMonitor(n_regions=4, decay_every=8)
    st = mon.init()
    for _ in range(2):
        st = mon.update(st, jnp.asarray([0, 0, 1, 2], jnp.int32))
    # second update crosses the decay boundary -> counters halved
    assert int(st.counts[0]) < 4
