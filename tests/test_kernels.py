"""Per-kernel allclose sweeps: Pallas (interpret=True on CPU) vs ref.py
oracles, across shapes and dtypes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.cms import cms_query, cms_update
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode
from repro.kernels.staged_scatter import staged_scatter


# ---------------------------------------------------------------------------
# staged_scatter
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("r,w,n,bw", [
    (16, 256, 8, 128),
    (64, 512, 32, 256),
    (8, 128, 8, 128),
    (128, 1024, 64, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_staged_scatter_matches_ref(r, w, n, bw, dtype):
    rng = np.random.RandomState(r + n)
    dest = jnp.asarray(rng.randn(r, w), dtype)
    staging = jnp.asarray(rng.randn(n, w), dtype)
    rows = jnp.asarray(rng.permutation(r)[:n], jnp.int32)  # unique (precondition)
    valid = jnp.asarray(rng.rand(n) > 0.3)
    out = staged_scatter(dest, staging, rows, valid, block_w=bw, interpret=True)
    expected = ref.staged_scatter_ref(dest, staging, rows, valid)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32))


def test_staged_scatter_all_invalid_is_noop():
    dest = jnp.ones((4, 128))
    staging = jnp.zeros((2, 128))
    out = staged_scatter(dest, staging, jnp.asarray([0, 1], jnp.int32),
                         jnp.zeros(2, bool), block_w=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dest))


# ---------------------------------------------------------------------------
# cms
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("depth,log2w,n", [(4, 12, 512), (2, 10, 300), (3, 8, 64),
                                           (4, 12, 1000)])
def test_cms_update_query_match_ref(depth, log2w, n):
    rng = np.random.RandomState(depth * n)
    counts = jnp.asarray(rng.randint(0, 5, (depth, 1 << log2w)), jnp.int32)
    ids = jnp.asarray(rng.randint(0, 10**6, n), jnp.int32)
    up = cms_update(counts, ids, interpret=True)
    up_ref = ref.cms_update_ref(counts, ids)
    np.testing.assert_array_equal(np.asarray(up), np.asarray(up_ref))
    q = cms_query(up, ids, interpret=True)
    q_ref = ref.cms_query_ref(up_ref, ids)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,hq,hkv,s,t,d,causal,window", [
    (2, 4, 2, 128, 128, 64, True, 0),
    (1, 8, 8, 256, 256, 32, True, 0),
    (2, 4, 1, 128, 256, 64, True, 0),    # GQA + chunked-prefill geometry
    (1, 4, 4, 128, 128, 64, False, 0),   # bidirectional (whisper encoder)
    (1, 4, 2, 256, 256, 64, True, 96),   # sliding window
    (1, 2, 2, 64, 64, 128, True, 0),
])
def test_flash_attention_matches_ref(b, hq, hkv, s, t, d, causal, window):
    rng = np.random.RandomState(s + t)
    q = jnp.asarray(rng.randn(b, hq, s, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, hkv, t, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, hkv, t, d), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    expected = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(1, 2, 128, 64), jnp.bfloat16)
    k = jnp.asarray(rng.randn(1, 2, 128, 64), jnp.bfloat16)
    v = jnp.asarray(rng.randn(1, 2, 128, 64), jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    expected = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=3e-2, rtol=3e-2)


# ---------------------------------------------------------------------------
# flash_decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,hq,hkv,t,d,bk", [
    (2, 4, 2, 512, 64, 128),
    (1, 8, 1, 1024, 128, 256),
    (3, 4, 4, 256, 32, 128),
])
def test_flash_decode_matches_ref(b, hq, hkv, t, d, bk):
    rng = np.random.RandomState(t)
    q = jnp.asarray(rng.randn(b, hq, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, t, hkv, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, t, hkv, d), jnp.float32)
    mask = jnp.asarray(rng.rand(b, t) > 0.4)
    out = flash_decode(q, k, v, mask, block_k=bk, interpret=True)
    expected = ref.flash_decode_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=2e-5, rtol=2e-5)


def test_flash_decode_single_valid_slot():
    """Degenerate mask: only one valid cache entry -> output == its value."""
    b, h, t, d = 1, 2, 128, 32
    q = jnp.ones((b, h, d))
    k = jnp.zeros((b, t, h, d)).at[0, 7].set(1.0)
    v = jnp.zeros((b, t, h, d)).at[0, 7].set(3.0)
    mask = jnp.zeros((b, t), bool).at[0, 7].set(True)
    out = flash_decode(q, k, v, mask, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out), 3.0, atol=1e-6)
