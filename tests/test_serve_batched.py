"""Continuous-batching scheduler: batched decode must be a pure throughput
optimization — bit-identical tokens to sequential per-request decode (the
same engine pinned to one slot AND the dense per-request ``ServeEngine``),
in every write mode, greedy and sampled, with EOS/max-len retirement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import synthetic_requests
from repro.models import build_model
from repro.serve import BatchConfig, BatchedServeEngine, ServeConfig, ServeEngine

N_REQ, PLEN, MAX_NEW = 5, 12, 10


def _model():
    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), 64)
    return cfg, model, params


def _queue(cfg, max_new=MAX_NEW):
    return synthetic_requests(N_REQ, PLEN, cfg.vocab, max_new, seed=3)


def _engine(model, params, mode, n_slots, **kw):
    kw.setdefault("segment_len", 4)
    kw.setdefault("ring_size", 4)
    kw.setdefault("hot_threshold", 3)
    return BatchedServeEngine(model, params, BatchConfig(
        max_seq=32, n_slots=n_slots, write_mode=mode, page_size=8, **kw))


@pytest.mark.parametrize("mode", ["direct", "staged", "adaptive"])
def test_batched_equals_sequential_and_per_request(mode):
    cfg, model, params = _model()
    eng_b = _engine(model, params, mode, n_slots=2)
    out_b = eng_b.serve(_queue(cfg))
    out_s = _engine(model, params, mode, n_slots=1).serve(_queue(cfg))
    assert set(out_b) == set(out_s) == set(range(N_REQ))
    for r in out_b:
        np.testing.assert_array_equal(out_b[r], out_s[r])
    # and against the dense per-request engine (different substrate:
    # contiguous lanes vs paged pool — identical greedy tokens)
    q = _queue(cfg)
    for r in range(N_REQ):
        req = q.pop()
        ref = ServeEngine(model, params, ServeConfig(
            max_seq=64, write_mode=mode, ring_size=4, page_size=8,
            hot_threshold=3,
        )).generate(jnp.asarray(req.prompt)[None], MAX_NEW)
        np.testing.assert_array_equal(out_b[r], np.asarray(ref)[0])
    assert eng_b.layout == "paged"
    total = eng_b.stats["direct_writes"] + eng_b.stats["staged_writes"]
    assert total == N_REQ * (MAX_NEW - 1)  # one KV write per decode step
    if mode == "staged":
        assert eng_b.stats["staged_writes"] == total


def test_staged_mode_drains_inside_the_scan():
    """ring_size < segment_len forces full-ring drains inside the jitted
    segment (not just the boundary drain)."""
    cfg, model, params = _model()
    eng = _engine(model, params, "staged", n_slots=2, segment_len=8,
                  ring_size=4)
    eng.serve(_queue(cfg))
    assert eng.stats["drains"] > 0


def test_adaptive_routes_a_mix_over_the_shared_pool():
    cfg, model, params = _model()
    eng = _engine(model, params, "adaptive", n_slots=2, hot_threshold=2)
    eng.serve(_queue(cfg))
    assert eng.stats["staged_writes"] > 0
    assert eng.stats["direct_writes"] > 0


def test_sampled_decode_keys_are_per_request():
    """Per-slot PRNG keys fold in the request id, so sampled outputs are a
    function of the request alone — identical across batch sizes."""
    cfg, model, params = _model()
    out_b = _engine(model, params, "direct", n_slots=2,
                    greedy=False).serve(_queue(cfg))
    out_s = _engine(model, params, "direct", n_slots=1,
                    greedy=False).serve(_queue(cfg))
    for r in out_b:
        np.testing.assert_array_equal(out_b[r], out_s[r])


def test_eos_retires_early_and_frees_the_slot():
    cfg, model, params = _model()
    base = _engine(model, params, "direct", n_slots=2).serve(_queue(cfg))
    # pick a token the greedy stream actually emits mid-sequence
    eos = int(base[0][4])
    eng = _engine(model, params, "direct", n_slots=2, eos_id=eos)
    out = eng.serve(_queue(cfg))
    assert len(out[0]) <= 5 and out[0][-1] == eos
    for r in out:  # every request stops at eos or budget, never beyond
        assert len(out[r]) <= MAX_NEW
        if len(out[r]) < MAX_NEW:
            assert out[r][-1] == eos
    assert eng.stats["retired"] == N_REQ
    assert not any(eng._occupied)


def test_max_new_one_needs_no_decode_step():
    cfg, model, params = _model()
    eng = _engine(model, params, "direct", n_slots=2)
    out = eng.serve(_queue(cfg, max_new=1))
    assert all(out[r].shape == (1,) for r in out)
    assert eng.stats["direct_writes"] == 0  # prefill-only

def test_segment_fn_compiles_once():
    cfg, model, params = _model()
    eng = _engine(model, params, "adaptive", n_slots=2)
    eng.serve(_queue(cfg))
    fn = eng._segment_fn
    assert fn is not None
    eng.reset()
    eng.serve(_queue(cfg))
    assert eng._segment_fn is fn  # reset keeps the compiled loop
