"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see ONE CPU device
(the 512-device override belongs exclusively to launch/dryrun.py)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session", autouse=True)
def _cpu_only():
    assert jax.default_backend() == "cpu"
    yield


@pytest.fixture()
def rng():
    return np.random.RandomState(0)


def seeds(n=5):
    """Deterministic seed sweep for the in-repo property harness
    (hypothesis is not installable in this offline container)."""
    return list(range(n))
