"""MoE dual-path dispatch: the uRDMA offload/unload equivalence properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.models import moe as MOE


def _cfg(no_drop=True):
    cfg = get_config("granite-moe-3b-a800m").reduced()
    if no_drop:
        cfg = dataclasses.replace(cfg, capacity_factor=100.0)
    return cfg


@pytest.mark.parametrize("seed", range(5))
def test_direct_equals_staged(seed):
    """PROPERTY: the offload (direct scatter) and unload (sort + drain)
    dispatch paths are bit-identical — including identical DROP sets under
    tight capacity (stable sort preserves arrival order within an expert)."""
    cfg = _cfg(no_drop=False)
    p = MOE.init_moe_mlp(cfg, jax.random.key(seed))
    x = jax.random.normal(jax.random.key(seed + 100), (2, 64, cfg.d_model))
    y_d, aux_d, load_d = MOE.moe_ffn_layer(cfg, p, x, "direct")
    y_s, aux_s, load_s = MOE.moe_ffn_layer(cfg, p, x, "staged")
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_s), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(load_d), np.asarray(load_s))


@pytest.mark.parametrize("seed", range(3))
def test_adaptive_equals_pure_paths(seed):
    """PROPERTY: adaptive (hot experts direct, cold staged) == either pure
    path when capacity doesn't drop — path choice is invisible (Idea 3)."""
    cfg = _cfg()
    p = MOE.init_moe_mlp(cfg, jax.random.key(seed))
    x = jax.random.normal(jax.random.key(seed + 7), (2, 32, cfg.d_model))
    hot = jnp.zeros((cfg.n_experts,), bool).at[: cfg.n_experts // 2].set(True)
    y_a, _, _ = MOE.moe_ffn_layer(cfg, p, x, "adaptive", hot)
    y_d, _, _ = MOE.moe_ffn_layer(cfg, p, x, "direct")
    np.testing.assert_allclose(np.asarray(y_a), np.asarray(y_d), atol=1e-5)


def test_expert_load_counts_assignments():
    cfg = _cfg()
    p = MOE.init_moe_mlp(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    _, _, load = MOE.moe_ffn_layer(cfg, p, x, "staged")
    assert int(jnp.sum(load)) == 2 * 16 * cfg.top_k


def test_capacity_drops_are_counted_not_crashed():
    cfg = dataclasses.replace(_cfg(no_drop=False), capacity_factor=0.25)
    p = MOE.init_moe_mlp(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model))
    y, _, _ = MOE.moe_ffn_layer(cfg, p, x, "staged")
    assert bool(jnp.all(jnp.isfinite(y)))


def test_router_weights_normalized():
    cfg = _cfg()
    p = MOE.init_moe_mlp(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (32, cfg.d_model))
    idx, w, aux, load = MOE.route(cfg, p, x)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, atol=1e-5)
    assert idx.shape == (32, cfg.top_k)
    assert float(aux) > 0


def test_moe_lm_dispatch_modes_agree():
    cfg = _cfg()
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    logits = {}
    params = None
    for mode in ("direct", "staged"):
        m = build_model(cfg, dispatch_mode=mode)
        params = params or m.init(jax.random.key(0), 32)
        logits[mode] = m.forward(params, tokens)
    np.testing.assert_allclose(np.asarray(logits["direct"]),
                               np.asarray(logits["staged"]), atol=1e-5)
