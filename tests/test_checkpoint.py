"""Checkpoint tests: atomicity, async, resume, elastic restore, pruning."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)),
                   "b": jnp.zeros((16,), jnp.float32)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 7, tree)
    out = ckpt.restore(str(tmp_path), jax.eval_shape(lambda: tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_prune(tmp_path):
    for s in (10, 20, 30, 40):
        ckpt.save(str(tmp_path), s, _tree())
    assert ckpt.latest_step(str(tmp_path)) == 40
    ckpt.prune(str(tmp_path), keep=2)
    assert ckpt.list_steps(str(tmp_path)) == [30, 40]


def test_async_save(tmp_path):
    t = ckpt.save_async(str(tmp_path), 5, _tree())
    t.join()
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_incomplete_checkpoint_ignored(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree())
    # simulate a crash mid-save: a .tmp dir + stale LATEST pointing at junk
    os.makedirs(tmp_path / "step_000000009.tmp")
    with open(tmp_path / "LATEST", "w") as f:
        f.write("step_000000009")
    assert ckpt.latest_step(str(tmp_path)) is None  # junk rejected
    assert ckpt.list_steps(str(tmp_path)) == [1]    # real one still there


def test_structure_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree())
    bad = {"params": {"w": jnp.zeros((8, 16))}, "step": jnp.asarray(0)}
    with pytest.raises(ValueError, match="structure mismatch"):
        ckpt.restore(str(tmp_path), bad)


def test_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree())
    bad = _tree()
    bad["params"]["w"] = jnp.zeros((4, 16))
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore(str(tmp_path), bad)


def test_elastic_restore_with_shardings(tmp_path):
    """Restore onto a (1x1) mesh with explicit NamedShardings — the code
    path that re-lays-out a checkpoint onto a different topology."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = _tree()
    ckpt.save(str(tmp_path), 3, tree)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = {
        "params": {"w": NamedSharding(mesh, P("data", "model")),
                   "b": NamedSharding(mesh, P(None))},
        "step": NamedSharding(mesh, P()),
    }
    out = ckpt.restore(str(tmp_path), jax.eval_shape(lambda: tree), shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))
    assert out["params"]["w"].sharding == sh["params"]["w"]
