"""Scheduler/allocator invariants (in-repo property harness, seed-swept):
no two live slots ever share a KV block, freed blocks are reused, retired
slots never write another byte into the pool, and admission preserves the
FIFO order of the request queue."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import seeds
from repro.configs import get_config
from repro.core.decision import DecisionModule
from repro.core.monitor import ExactMonitor
from repro.core.policy import FrequencyPolicy
from repro.core.types import make_write_batch
from repro.data import synthetic_requests
from repro.kvcache import BlockPool
from repro.models import build_model
from repro.serve import BatchConfig, BatchedServeEngine


# ---------------------------------------------------------------------------
# BlockPool properties
# ---------------------------------------------------------------------------


def test_block_pool_ownership_is_disjoint_under_random_churn():
    for seed in seeds():
        rng = np.random.RandomState(seed)
        pool = BlockPool(24)
        held = {}
        for _ in range(200):
            slot = int(rng.randint(0, 6))
            if slot in held and rng.rand() < 0.5:
                freed = pool.free_slot(slot)
                assert sorted(freed) == sorted(held.pop(slot))
            else:
                got = pool.alloc(slot, int(rng.randint(1, 4)))
                if got is None:
                    assert pool.n_free < 4  # only refuses when short
                    continue
                held.setdefault(slot, []).extend(int(b) for b in got)
            # audit: owner table == held map, blocks disjoint
            owners = {}
            for s, blocks in held.items():
                for b in blocks:
                    assert b not in owners, "block shared by two slots"
                    owners[b] = s
                    assert pool.owner[b] == s
            assert pool.n_free == 24 - len(owners)


def test_block_pool_freed_blocks_are_reused():
    pool = BlockPool(4)
    first = pool.alloc(0, 4)
    assert pool.alloc(1, 1) is None          # exhausted, no partial alloc
    pool.free_slot(0)
    second = pool.alloc(1, 4)
    assert sorted(first.tolist()) == sorted(second.tolist())


# ---------------------------------------------------------------------------
# Engine-level invariants
# ---------------------------------------------------------------------------


def _setup(n_slots=2, n_blocks=0, max_new=6, n_req=5, mode="adaptive"):
    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), 32)
    queue = synthetic_requests(n_req, 8, cfg.vocab, max_new, seed=2)
    eng = BatchedServeEngine(model, params, BatchConfig(
        max_seq=32, n_slots=n_slots, segment_len=3, page_size=4,
        write_mode=mode, ring_size=3, hot_threshold=2, n_blocks=n_blocks,
    ))
    return eng, queue


def test_admission_preserves_fifo_order():
    """Admission order == submission order for a uniform workload, even
    when the pool is too small to admit every waiting request: skip-ahead
    only reorders when a LATER request needs strictly fewer blocks than a
    blocked earlier one, so same-size streams stay strictly FIFO — and
    dict insertion order records the admission order."""
    for n_blocks in (0, 7):  # ample pool / pool forcing waits (3 pages/req)
        eng, queue = _setup(n_slots=4, n_blocks=n_blocks, n_req=6)
        out = eng.serve(queue)
        assert list(out) == list(range(6))


def test_admission_skips_blocked_head_to_smaller_request():
    """Head-of-line fix: a request the pool can't cover RIGHT NOW is
    skipped in favor of a later one that fits; it keeps its queue position
    and completes once blocks free up."""
    from repro.data import RequestQueue

    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), 32)
    eng = BatchedServeEngine(model, params, BatchConfig(
        max_seq=32, n_slots=2, segment_len=3, page_size=4, n_blocks=5))
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab
    q = RequestQueue()
    q.submit(prompt, 1)   # req 0: 2 pages
    q.submit(prompt, 9)   # req 1: 4 pages — blocked after req 0 takes 2
    q.submit(prompt, 1)   # req 2: 2 pages — fits, overtakes req 1
    eng.admit(q)
    assert eng._slot_req == [0, 2]   # req 1 skipped, not dropped
    assert len(q) == 1 and q.peek().req_id == 1
    out = eng.serve(q)               # req 1 admitted after retirements
    assert set(out) == {0, 1, 2}
    assert len(out[1]) == 9


def _chunked_setup(plens, max_new, n_req, n_blocks=0, chunk_size=3,
                   segment_len=2, n_slots=2):
    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), 40)
    queue = synthetic_requests(n_req, plens, cfg.vocab, max_new, seed=2)
    eng = BatchedServeEngine(model, params, BatchConfig(
        max_seq=40, n_slots=n_slots, segment_len=segment_len, page_size=4,
        n_blocks=n_blocks, chunked=True, chunk_size=chunk_size))
    return eng, queue


def test_chunked_phase_transitions_and_cursor_invariants():
    """A slot never decodes (= never emits) before its chunk cursor passes
    plen; the cursor never overshoots plen mid-prefill; decode positions
    start at plen."""
    eng, queue = _chunked_setup(plens=[13, 4], max_new=4, n_req=4)
    from repro.serve.scheduler import PHASE_DECODE, PHASE_PREFILL

    for _ in range(200):
        eng.retire_done()
        eng.admit(queue)
        if not any(eng._occupied):
            break
        enabled = eng._topup_blocks()
        eng.run_segment(enabled)
        pos = np.asarray(eng.slots.pos)
        phase = np.asarray(eng.slots.phase)
        for s in range(eng.cfg.n_slots):
            if not eng._occupied[s]:
                continue
            plen = eng._slot_plen[s]
            rid = eng._slot_req[s]
            if phase[s] == PHASE_PREFILL:
                assert pos[s] <= plen
                assert len(eng.outputs[rid]) == 0  # no decode before flip
            else:
                assert phase[s] == PHASE_DECODE and pos[s] >= plen
            assert len(eng.outputs[rid]) <= eng._slot_max_new[s]
    else:
        raise AssertionError("did not drain")
    assert all(len(t) == 4 for t in eng.outputs.values())


def test_per_chunk_alloc_grows_incrementally_and_never_overlaps():
    """Per-chunk granularity: admission reserves only the first segment's
    pages (a long prompt does NOT pin its whole footprint), top-ups grow
    the page table monotonically, and block ownership stays disjoint."""
    eng, queue = _chunked_setup(plens=[24], max_new=9, n_req=3,
                                chunk_size=2, segment_len=2)
    full = eng._pages_needed(24, 9)          # whole-footprint pages
    eng.admit(queue)
    slot0_pages = eng._slot_pages[0]
    assert 0 < slot0_pages < full            # incremental, not up-front
    seen_pages = {}  # (slot, req) -> page count, monotone per request
    for _ in range(200):
        eng.retire_done()
        eng.admit(queue)
        if not any(eng._occupied):
            break
        enabled = eng._topup_blocks()
        # ownership audit: page tables of occupied slots reference
        # disjoint, owned blocks (per-chunk allocs never overlap)
        table = np.asarray(eng.cache["page_table"])
        seen = set()
        for s in range(eng.cfg.n_slots):
            blocks = [b for b in table[s] if b >= 0]
            if not eng._occupied[s]:
                assert not blocks
                continue
            key = (s, eng._slot_req[s])
            assert eng._slot_pages[s] >= seen_pages.get(key, 0)  # monotone
            seen_pages[key] = eng._slot_pages[s]
            for b in blocks:
                assert b not in seen
                seen.add(b)
                assert eng.pool.owner[b] == s
        eng.run_segment(enabled)
    else:
        raise AssertionError("did not drain")
    assert all(len(t) == 9 for t in eng.outputs.values())


def test_chunked_stalls_instead_of_deadlocking_on_a_tight_pool():
    """A slot whose top-up fails is stalled for the segment (enabled mask)
    and resumes once blocks free; the stream still completes, bit-equal to
    an ample-pool run."""
    ample, q1 = _chunked_setup(plens=[16, 8], max_new=6, n_req=4)
    out_ref = ample.serve(q1)
    # peak concurrent demand is 6+4=10 pages; 9 forces top-up stalls while
    # any single request (<=6) still fits, so the stream must complete
    tight, q2 = _chunked_setup(plens=[16, 8], max_new=6, n_req=4,
                               n_blocks=9)
    out = tight.serve(q2)
    assert set(out) == set(out_ref)
    for r in out:
        np.testing.assert_array_equal(out[r], out_ref[r])


def test_live_slots_never_share_blocks_and_tables_match_owner():
    eng, queue = _setup(n_req=5)
    for _ in range(200):
        eng.retire_done()
        eng.admit(queue)
        if not any(eng._occupied):
            break
        # page tables of occupied slots reference disjoint, owned blocks
        table = np.asarray(eng.cache["page_table"])
        seen = set()
        for s in range(eng.cfg.n_slots):
            blocks = [b for b in table[s] if b >= 0]
            if not eng._occupied[s]:
                assert not blocks
                continue
            for b in blocks:
                assert b not in seen
                seen.add(b)
                assert eng.pool.owner[b] == s
        eng.run_segment()
    else:
        raise AssertionError("did not drain")


def test_retired_slots_never_write():
    """After a request retires and its blocks return to the pool, nothing
    touches them until reallocation — decode continues on the other slot."""
    eng, queue = _setup(n_slots=2, max_new=3, n_req=2)
    q2 = synthetic_requests(1, 8, 256, 14, seed=5)  # long request, slot 1
    q2._q[0].req_id = 99
    eng.admit(queue)   # two short requests
    eng.run_segment()  # max_new 3 -> both done after 2 decode steps
    assert eng.retire_done() == 2
    freed = [b for b in range(eng.pool.n_blocks) if eng.pool.owner[b] == -1]
    eng.admit(q2)      # long request reuses SOME freed blocks
    held = set(np.asarray(eng.cache["page_table"])[:, :].ravel().tolist())
    untouched = [b for b in freed if b not in held]
    assert untouched, "need at least one freed, un-reallocated block"
    snap_k = np.asarray(eng.cache["pages_k"][:, untouched])
    snap_v = np.asarray(eng.cache["pages_v"][:, untouched])
    while any(eng._occupied):           # decode the long request to the end
        eng.run_segment()
        eng.retire_done()
    np.testing.assert_array_equal(
        np.asarray(eng.cache["pages_k"][:, untouched]), snap_k)
    np.testing.assert_array_equal(
        np.asarray(eng.cache["pages_v"][:, untouched]), snap_v)


def test_inactive_slots_do_not_heat_the_monitor():
    """DecisionModule with an active mask: masked requests update neither
    counters nor totals and are excluded from routing/stats."""
    mon = ExactMonitor(n_regions=8)
    dm = DecisionModule(policy=FrequencyPolicy(monitor=mon, threshold=2),
                        monitor=mon)
    state = dm.init_state()
    batch = make_write_batch(jnp.asarray([3, 3, 5], jnp.int32))
    active = jnp.asarray([True, True, False])
    for _ in range(3):
        unload, state, stats = dm(state, batch, active=active)
    assert state.counts[3] == 6 and state.counts[5] == 0
    assert int(state.total) == 6
    assert not bool(unload[2])  # inactive never routes anywhere
    assert int(stats.n_offloaded + stats.n_unloaded) == 2


def test_retired_slots_never_write_in_lanes_layout():
    """The lanes layout must hold the same invariant: a retired slot's
    cache lane is frozen (its scatter rows redirect to the drop sentinel)
    while the other slot keeps decoding."""
    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), 32)
    eng = BatchedServeEngine(model, params, BatchConfig(
        max_seq=32, n_slots=2, segment_len=3, page_size=4,
        kv_layout="lanes",
    ))
    q = synthetic_requests(1, 8, cfg.vocab, 3, seed=2)   # retires fast
    q2 = synthetic_requests(1, 8, cfg.vocab, 12, seed=4)  # keeps decoding
    q2._q[0].req_id = 1
    eng.admit(q)
    eng.admit(q2)
    eng.run_segment()
    done = np.asarray(eng.slots.done)
    assert bool(done[0]) and not bool(done[1])
    snap = np.asarray(eng.cache["k"][:, 0])  # slot 0 lane, NOT retired yet
    while any(eng._occupied):
        eng.run_segment()
        eng.retire_done()
    np.testing.assert_array_equal(np.asarray(eng.cache["k"][:, 0]), snap)


def test_hysteresis_masked_route_is_deterministic_on_shared_buckets():
    """A masked (retired) lane holding a stale region id that an active
    lane also writes must not race the decision memory: only active lanes
    record, so the bucket deterministically holds the active band."""
    from repro.core.policy import HysteresisPolicy

    pol = HysteresisPolicy(monitor=ExactMonitor(n_regions=8), lo=2, hi=4)
    state = pol.init_state()
    batch = make_write_batch(jnp.asarray([7, 7], jnp.int32))
    mask = jnp.asarray([False, True])
    unload, state = pol.route(state, batch, mask=mask)
    # est(7)=1 < lo -> active lane banded unload; masked lane wrote nothing
    assert bool(state.last_unload[7])
    assert unload.tolist() == [False, True]
    assert int(state.mon.counts[7]) == 1  # masked lane didn't count either
    # mask everything: memory and counters must be untouched
    _, state2 = pol.route(state, batch, mask=jnp.zeros((2,), bool))
    np.testing.assert_array_equal(np.asarray(state2.last_unload),
                                  np.asarray(state.last_unload))
    assert int(state2.mon.counts[7]) == 1


def test_monitor_counts_follow_interleaved_multi_slot_stream():
    """The adaptive engine's page counters tally EXACTLY the blocks the
    live slots wrote (prefill + decode), i.e. the interleaved stream."""
    eng, queue = _setup(n_req=3, max_new=5, mode="adaptive")
    eng.serve(queue)
    counts = np.asarray(eng.mon_state.counts)
    # 3 requests x (8 prompt rows + 4 decode rows) = 36 monitored writes
    assert counts.sum() == 3 * (8 + 4)
    assert int(eng.mon_state.total) == 3 * (8 + 4)
