"""WritePath / RoutingPolicy registries: name resolution (loud errors
listing what IS registered), capability negotiation (incompatible
path+policy+layout combos refuse construction), and third-party
extension (a toy WritePath registered in-test round-trips through the
batched serving engine)."""
import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.decision import DecisionModule
from repro.core.paths import (
    CAP_BULK_PIN,
    CAP_DIRECT,
    CAP_STAGED,
    WritePath,
    available_paths,
    build_decision,
    get_path,
    negotiate,
    register_path,
)
from repro.core.policy import (
    AlwaysUnload,
    FrequencyPolicy,
    available_policies,
    get_policy_factory,
    register_policy,
)
from repro.data import synthetic_requests
from repro.models import build_model
from repro.serve import BatchConfig, BatchedServeEngine, Engine, EngineConfig


def _engine(model, params, **kw):
    kw.setdefault("max_seq", 32)
    kw.setdefault("n_slots", 2)
    kw.setdefault("segment_len", 4)
    kw.setdefault("page_size", 8)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return BatchedServeEngine(model, params, BatchConfig(**kw))


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), 64)
    return cfg, model, params


# ---------------------------------------------------------------------------
# name resolution
# ---------------------------------------------------------------------------

def test_builtin_names_are_registered():
    assert {"direct", "staged", "adaptive"} <= set(available_paths())
    assert {"always-offload", "always-unload", "hint", "frequency",
            "hysteresis"} <= set(available_policies())


def test_unknown_path_name_lists_registered():
    with pytest.raises(ValueError) as exc:
        get_path("bogus-path")
    msg = str(exc.value)
    for name in ("direct", "staged", "adaptive"):
        assert name in msg


def test_unknown_policy_name_lists_registered():
    with pytest.raises(ValueError) as exc:
        get_policy_factory("bogus-policy")
    msg = str(exc.value)
    for name in ("always-offload", "frequency", "hysteresis"):
        assert name in msg


def test_engine_config_surfaces_registry_errors(setup):
    _, model, params = setup
    with pytest.raises(ValueError, match="registered paths"):
        _engine(model, params, path="bogus-path")
    with pytest.raises(ValueError, match="registered policies"):
        _engine(model, params, path="adaptive", policy="bogus-policy")


def test_double_registration_is_refused():
    with pytest.raises(ValueError, match="already registered"):
        register_path(get_path("direct"))
    with pytest.raises(ValueError, match="already registered"):
        register_policy("frequency", lambda **kw: None)


def test_path_validates_its_own_capabilities():
    with pytest.raises(ValueError, match="unknown capabilities"):
        WritePath(name="x", capabilities=frozenset({"warp"}),
                  uses_ring=False, default_policy="always-offload")
    with pytest.raises(ValueError, match="uses_ring"):
        WritePath(name="x", capabilities=frozenset({CAP_STAGED}),
                  uses_ring=False, default_policy="always-unload")


# ---------------------------------------------------------------------------
# capability negotiation
# ---------------------------------------------------------------------------

def test_unloading_policy_needs_staged_capability():
    with pytest.raises(ValueError, match="'staged' capability"):
        build_decision("direct", "frequency", n_regions=8)
    with pytest.raises(ValueError, match="'staged' capability"):
        build_decision("direct", "always-unload", n_regions=8)


def test_offloading_policy_needs_direct_capability():
    only_staged = WritePath(
        name="pure-staged", capabilities=frozenset({CAP_STAGED}),
        uses_ring=True, default_policy="always-unload")
    negotiate(only_staged, AlwaysUnload())  # unload-only: fine
    with pytest.raises(ValueError, match="lacks the 'direct'"):
        negotiate(only_staged, FrequencyPolicy(threshold=1))
    # bulk-pin does NOT substitute for direct on scattered writes: the
    # built-in staged path refuses adaptive-routing policies
    with pytest.raises(ValueError, match="lacks the 'direct'"):
        build_decision("staged", "frequency", n_regions=8)


def test_lanes_layout_rejects_staged_capable_paths(setup):
    _, model, params = setup
    for path in ("staged", "adaptive"):
        with pytest.raises(ValueError, match="lanes.*direct-only"):
            _engine(model, params, kv_layout="lanes", path=path)
    # and through the legacy write_mode alias on an SWA (lanes-only) arch
    cfg = get_config("h2o-danube-3-4b").reduced()
    swa_model = build_model(cfg)
    swa_params = swa_model.init(jax.random.key(0), 32)
    with pytest.raises(ValueError, match="direct-only"):
        _engine(swa_model, swa_params, write_mode="staged")


def test_chunked_needs_bulk_pin():
    no_bulk = WritePath(
        name="no-bulk", capabilities=frozenset({CAP_DIRECT, CAP_STAGED}),
        uses_ring=True, default_policy="frequency")
    negotiate(no_bulk, FrequencyPolicy(threshold=1), chunked=False)
    with pytest.raises(ValueError, match="bulk-pin"):
        negotiate(no_bulk, FrequencyPolicy(threshold=1), chunked=True)


def test_from_names_builds_working_modules():
    for path, policy in (("direct", None), ("staged", None),
                         ("adaptive", None), ("adaptive", "hysteresis")):
        dm = DecisionModule.from_names(policy, path=path, n_regions=8,
                                       hot_threshold=3)
        state = dm.init_state()
        from repro.core.types import make_write_batch
        import jax.numpy as jnp
        unload, state, stats = dm(
            state, make_write_batch(jnp.asarray([1, 2], jnp.int32)))
        assert unload.shape == (2,)


def test_old_constructors_warn_deprecation(setup):
    """The legacy entry points are shims for one release: constructing
    them warns, pointing at Engine.from_config (the facade constructs
    them internally with the warning suppressed)."""
    from repro.serve import ServeConfig, ServeEngine

    _, model, params = setup
    with pytest.warns(DeprecationWarning, match="Engine.from_config"):
        BatchedServeEngine(model, params, BatchConfig(max_seq=32, n_slots=1))
    with pytest.warns(DeprecationWarning, match="Engine.from_config"):
        ServeEngine(model, params, ServeConfig(max_seq=32))
    import warnings as W
    with W.catch_warnings():
        W.simplefilter("error", DeprecationWarning)
        Engine.from_config(EngineConfig(max_seq=32, n_slots=1),
                           model, params)  # facade itself must not warn


# ---------------------------------------------------------------------------
# third-party extension round-trip
# ---------------------------------------------------------------------------

def test_toy_write_path_round_trips_through_the_engine(setup):
    """A WritePath registered by a third party is constructible by name
    and serves bit-identically to the built-in with the same mechanics
    (the path declares its contract; the engine supplies the machinery)."""
    cfg, model, params = setup
    name = "toy-ring"
    if name not in available_paths():
        register_path(WritePath(
            name=name,
            capabilities=frozenset({CAP_DIRECT, CAP_STAGED, CAP_BULK_PIN}),
            uses_ring=True,
            default_policy="always-unload",
            description="test-registered clone of the staged mechanics",
        ))
    queue = lambda: synthetic_requests(4, 9, cfg.vocab, 6, seed=3)  # noqa: E731
    out_toy = _engine(model, params, path=name).serve(queue())
    out_ref = _engine(model, params, write_mode="staged").serve(queue())
    assert set(out_toy) == set(out_ref)
    for r in out_toy:
        np.testing.assert_array_equal(out_toy[r], out_ref[r])
    # and through the Engine facade front door
    eng = Engine.from_config(EngineConfig(
        max_seq=32, n_slots=2, segment_len=4, page_size=8, path=name),
        model, params)
    out_face = eng.serve(queue())
    for r in out_face:
        np.testing.assert_array_equal(out_face[r], out_ref[r])
    assert eng.scheduler.path.name == name
    assert eng.scheduler.uses_ring
