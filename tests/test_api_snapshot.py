"""Public-API snapshot: the exported symbols AND call signatures of
``repro.serve`` and ``repro.core.paths`` are committed
(``tests/api_snapshot.txt``) and diffed here — an unreviewed change to
the serving front door or the write-path registry fails CI instead of
silently breaking downstream configs.

Refresh after an INTENTIONAL surface change::

    PYTHONPATH=src python tests/test_api_snapshot.py --update
"""
import importlib
import inspect
import os
import sys

SNAPSHOT_MODULES = ("repro.serve", "repro.core.paths")
SNAPSHOT_FILE = os.path.join(os.path.dirname(__file__), "api_snapshot.txt")


def _describe(prefix: str, obj) -> list:
    lines = []
    if inspect.isclass(obj):
        try:
            lines.append(f"{prefix}{inspect.signature(obj)}")
        except (ValueError, TypeError):
            lines.append(f"{prefix}(...)")
        for name, member in sorted(vars(obj).items()):
            if name.startswith("_"):
                continue
            if isinstance(member, (classmethod, staticmethod)):
                fn = member.__func__
                lines.append(f"{prefix}.{name}{inspect.signature(fn)}")
            elif inspect.isfunction(member):
                lines.append(f"{prefix}.{name}{inspect.signature(member)}")
            elif isinstance(member, property):
                lines.append(f"{prefix}.{name} <property>")
    elif callable(obj):
        lines.append(f"{prefix}{inspect.signature(obj)}")
    else:
        lines.append(f"{prefix} = {obj!r}")
    return lines


def current_snapshot() -> str:
    lines = []
    for modname in SNAPSHOT_MODULES:
        mod = importlib.import_module(modname)
        for name in sorted(mod.__all__):
            lines.extend(_describe(f"{modname}.{name}", getattr(mod, name)))
    return "\n".join(lines) + "\n"


def test_public_api_matches_snapshot():
    with open(SNAPSHOT_FILE) as f:
        committed = f.read()
    current = current_snapshot()
    if current != committed:
        import difflib

        diff = "\n".join(difflib.unified_diff(
            committed.splitlines(), current.splitlines(),
            "api_snapshot.txt (committed)", "current", lineterm=""))
        raise AssertionError(
            "public API surface drifted from tests/api_snapshot.txt.\n"
            "If intentional, refresh with:\n"
            "    PYTHONPATH=src python tests/test_api_snapshot.py --update\n"
            f"{diff}")


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    if "--update" in sys.argv:
        with open(SNAPSHOT_FILE, "w") as f:
            f.write(current_snapshot())
        print(f"wrote {SNAPSHOT_FILE}")
    else:
        print(current_snapshot(), end="")
