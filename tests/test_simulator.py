"""Fig. 3 reproduction: validate the calibrated simulator against the
paper's own claims (§4, FIG3_CLAIMS) using the REAL policy code."""
import jax
import jax.numpy as jnp

from repro.configs import FIG3_CLAIMS
from repro.core.monitor import ExactMonitor
from repro.core.policy import AlwaysOffload, AlwaysUnload, FrequencyPolicy, HintPolicy
from repro.core.simulator import RDMASimulator, sweep_point, zipf_regions

N, W = 60_000, 6_000  # reduced from the paper's 5M; steady-state average


def _avg(policy, n_regions, monitor=None, seed=0):
    avg, _ = sweep_point(jax.random.key(seed), n_regions, N, W, policy, monitor)
    return avg


def test_offload_all_hit_latency():
    """Paper: ~2.6 us RTT with 1 region (no MTT capacity misses)."""
    avg = _avg(AlwaysOffload(), 1)
    assert abs(avg - FIG3_CLAIMS["offload_rtt_1_region"]) < 0.1


def test_offload_degrades_2x_at_2e20_regions():
    """Paper: ~5.1 us at 2^20 regions (~2x degradation)."""
    avg = _avg(AlwaysOffload(), 2**20)
    assert abs(avg - FIG3_CLAIMS["offload_rtt_2e20_regions"]) < 0.3


def test_unload_flat_across_region_counts():
    """Paper: unload path ~3.4 us, 'stays almost unaffected'."""
    lats = [_avg(AlwaysUnload(), r) for r in (1, 2**10, 2**20)]
    assert all(abs(l - FIG3_CLAIMS["unload_rtt_flat"]) < 0.2 for l in lats)
    assert max(lats) - min(lats) < 0.25  # flatness


def test_improvement_at_2e20_is_about_31pct():
    off = _avg(AlwaysOffload(), 2**20)
    un = _avg(AlwaysUnload(), 2**20)
    improvement = 1.0 - un / off
    assert abs(improvement - FIG3_CLAIMS["improvement_at_2e20"]) < 0.05


def test_adaptive_matches_best_of_both():
    """Paper: adaptive (hint top-4096) matches the best line everywhere,
    and can beat both mid-range."""
    for r in (1, 2**12, 2**17, 2**20):
        hot = jnp.zeros((r,), bool).at[: min(4096, r)].set(True)
        ad = _avg(HintPolicy(hot_regions=hot), r)
        off = _avg(AlwaysOffload(), r)
        un = _avg(AlwaysUnload(), r)
        assert ad <= min(off, un) + 0.15, (r, ad, off, un)


def test_adaptive_beats_both_midrange():
    r = 2**14
    hot = jnp.zeros((r,), bool).at[:4096].set(True)
    ad = _avg(HintPolicy(hot_regions=hot), r)
    off = _avg(AlwaysOffload(), r)
    un = _avg(AlwaysUnload(), r)
    assert ad < min(off, un) - 0.1  # strictly better in the crossover zone


def test_frequency_policy_tracks_hint_policy():
    """The frequency-based policy (monitor-driven) should approach the
    hint-based (oracle) policy's latency."""
    r = 2**16
    mon = ExactMonitor(n_regions=r)
    freq = _avg(FrequencyPolicy(monitor=mon, threshold=3), r, monitor=mon)
    hot = jnp.zeros((r,), bool).at[:4096].set(True)
    hint = _avg(HintPolicy(hot_regions=hot), r)
    assert freq < hint + 0.4


def test_zipf_skew():
    ids = zipf_regions(jax.random.key(0), 50_000, 1024, skew=0.5)
    import numpy as np

    counts = np.bincount(np.asarray(ids), minlength=1024)
    # Zipf(0.5): head regions much hotter than tail
    assert counts[:16].mean() > 4 * counts[-256:].mean()


def test_unload_writes_bypass_mtt():
    """Unloaded writes must not touch the MTT cache (they hit the staging
    buffer whose translation is resident)."""
    sim = RDMASimulator()
    regions = jnp.asarray([5, 5, 5, 5], jnp.int32)
    res = sim.run(regions, jnp.asarray([True, True, True, True]))
    assert int(res.mtt_hits) == 0
    res2 = sim.run(regions, jnp.asarray([False, False, False, False]))
    assert int(res2.mtt_hits) == 3  # first is a compulsory miss
