"""Training integration: loss descent, grad accumulation equivalence,
checkpoint resume, fault retry, straggler detection, MoE monitor flow."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig, Pipeline, SyntheticSource
from repro.models import build_model
from repro.optim import AdamW, warmup_cosine
from repro.train import (
    Trainer,
    TrainerConfig,
    init_train_state,
    make_train_step,
)


def _setup(arch="stablelm-1.6b", microbatches=1, n_hot=0):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    opt = AdamW(lr=1e-3)
    state = init_train_state(model, opt, jax.random.key(0), 32,
                             n_hot_experts=n_hot)
    step = jax.jit(make_train_step(model, opt, microbatches=microbatches,
                                   n_hot_experts=n_hot))
    dc = DataConfig(seq_len=32, global_batch=8, vocab=cfg.vocab)
    return cfg, model, opt, state, step, dc


def test_loss_decreases():
    cfg, model, opt, state, step, dc = _setup()
    pipe = Pipeline(SyntheticSource(dc))
    tr = Trainer(step, state, pipe, TrainerConfig(total_steps=25, log_every=100))
    res = tr.run()
    assert res["final_loss"] < tr.history[0]


def test_grad_accum_equivalence():
    """microbatches=4 must produce (numerically) the same update as
    microbatches=1 on the same global batch."""
    cfg, model, opt, s1, step1, dc = _setup(microbatches=1)
    _, _, _, s4, step4, _ = _setup(microbatches=4)
    batch = {k: jnp.asarray(v) for k, v in SyntheticSource(dc).batch_at(0).items()}
    s1b, m1 = step1(s1, batch)
    s4b, m4 = step4(s4, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    a = jax.tree.leaves(s1b.params)[0]
    b = jax.tree.leaves(s4b.params)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_checkpoint_resume_bitexact():
    """Train 10; checkpoint at 5; resume a fresh trainer -> states match."""
    cfg, model, opt, state, step, dc = _setup()
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainerConfig(total_steps=10, checkpoint_every=5,
                             checkpoint_dir=d, log_every=100)
        tr = Trainer(step, state, Pipeline(SyntheticSource(dc)), tcfg)
        tr.run()

        state2 = init_train_state(model, opt, jax.random.key(0), 32)
        tr2 = Trainer(step, state2, Pipeline(SyntheticSource(dc)), tcfg)
        tr2.maybe_resume()
        assert int(tr2.state.step) == 10
        a = jax.tree.leaves(tr.state.params)[0]
        b = jax.tree.leaves(tr2.state.params)[0]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fault_retry_recovers():
    cfg, model, opt, state, step, dc = _setup()
    tr = Trainer(step, state, Pipeline(SyntheticSource(dc)),
                 TrainerConfig(total_steps=6, max_retries=2, log_every=100))
    boom = {"left": 2}

    def fault_hook(s):
        if s == 3 and boom["left"] > 0:
            boom["left"] -= 1
            raise RuntimeError("injected preemption")

    res = tr.run(fault_hook=fault_hook)
    assert res["steps"] == 6
    assert res["retries"] == 2


def test_fault_exhausts_retries():
    cfg, model, opt, state, step, dc = _setup()
    tr = Trainer(step, state, Pipeline(SyntheticSource(dc)),
                 TrainerConfig(total_steps=4, max_retries=1, log_every=100))

    def always_fail(s):
        if s == 2:
            raise RuntimeError("hard failure")

    with pytest.raises(RuntimeError):
        tr.run(fault_hook=always_fail)


def test_straggler_detection():
    import time

    cfg, model, opt, state, step, dc = _setup()

    slow = {"at": 15}

    def stall_hook(s):
        if s == slow["at"]:
            time.sleep(1.0)  # way above the EWMA of CPU smoke steps

    tr = Trainer(step, state, Pipeline(SyntheticSource(dc)),
                 TrainerConfig(total_steps=20, straggler_factor=3.0,
                               straggler_warmup=5, log_every=100))
    # wrap train_step to inject the stall INSIDE the timed region
    orig = tr.train_step

    def slow_step(state, batch):
        stall_hook(int(state.step))
        return orig(state, batch)

    tr.train_step = slow_step
    res = tr.run()
    assert res["stragglers"] >= 1


def test_moe_monitor_updates_hot_mask():
    """Expert-load counters accumulate and the adaptive hot-mask refreshes
    between steps (the paper's off-critical-path recalibration)."""
    cfg, model, opt, state, step, dc = _setup("granite-moe-3b-a800m",
                                              n_hot=2)
    batch = {k: jnp.asarray(v) for k, v in SyntheticSource(dc).batch_at(0).items()}
    assert state.expert_counts is not None
    s1, _ = step(state, batch)
    assert int(jnp.sum(s1.expert_counts)) > 0
    assert int(jnp.sum(s1.hot_mask)) == 2  # top-2 experts hot
    s2, _ = step(s1, batch)
    assert int(jnp.sum(s2.expert_counts)) > int(jnp.sum(s1.expert_counts))


def test_warmup_cosine_schedule():
    sched = warmup_cosine(1e-3, 10, 100, floor=0.1)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert abs(float(sched(jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(sched(jnp.asarray(100))) == pytest.approx(1e-4, rel=1e-3)
    assert float(sched(jnp.asarray(55))) < 1e-3
