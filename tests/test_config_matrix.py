"""Cross-config smoke matrix: EVERY registered architecture must serve
through the continuous-batching engine — tiny variant, real prefill +
decode steps, admission AND retirement exercised (3 requests over 2 slots).

This is the drift net: a config/model-builder change that only breaks at
launch time (cache layout, media plumbing, decode signature) surfaces here
instead. Dense non-SWA archs go through the paged pool (adaptive routing);
every other family serves from dense lanes (direct mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.data import synthetic_requests
from repro.models import build_model, media_spec, needs_media
from repro.serve import BatchConfig, BatchedServeEngine

MAX_SEQ, PLEN, MAX_NEW = 32, 8, 5


def _expected_layout(cfg, model):
    from repro.models.transformer import DecoderLM

    if isinstance(model, DecoderLM) and not model.is_vlm \
            and not cfg.sliding_window:
        return "paged"
    return "lanes"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_batched_serve_smoke(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), MAX_SEQ)
    media_shape = None
    if needs_media(cfg):
        media_shape = media_spec(cfg, 1, jnp.float32).shape[1:]
    queue = synthetic_requests(3, PLEN, cfg.vocab, MAX_NEW, seed=7,
                               media_shape=media_shape)
    layout = _expected_layout(cfg, model)
    eng = BatchedServeEngine(model, params, BatchConfig(
        max_seq=MAX_SEQ, n_slots=2, segment_len=2, page_size=4,
        write_mode="adaptive" if layout == "paged" else "direct",
        ring_size=2, hot_threshold=2,
    ))
    assert eng.layout == layout
    out = eng.serve(queue)

    assert set(out) == {0, 1, 2}
    for r, toks in out.items():
        assert toks.shape == (MAX_NEW,)
        assert toks.dtype == np.int32
        assert (0 <= toks).all() and (toks < cfg.vocab).all()
    # 3 requests over 2 slots: the third admission needs a retirement
    assert eng.stats["admitted"] == 3 and eng.stats["retired"] == 3
    assert eng.stats["direct_writes"] + eng.stats["staged_writes"] \
        == 3 * (MAX_NEW - 1)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_chunked_prefill_bit_parity(arch):
    """chunked=True vs whole-prompt admission: identical token streams on
    EVERY arch, over a mixed-length prompt stream whose lengths are ragged
    against the chunk size. Paged archs run the in-scan mixed-phase path;
    lanes archs chunk-prefill at admission through model.chunk_prefill —
    either way, chunking is invisible in the output."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), MAX_SEQ)
    media_shape = None
    if needs_media(cfg):
        media_shape = media_spec(cfg, 1, jnp.float32).shape[1:]
    outs = {}
    for chunked in (False, True):
        queue = synthetic_requests(3, [PLEN, 5], cfg.vocab, MAX_NEW, seed=7,
                                   media_shape=media_shape)
        eng = BatchedServeEngine(model, params, BatchConfig(
            max_seq=MAX_SEQ, n_slots=2, segment_len=2, page_size=4,
            chunked=chunked, chunk_size=3,
        ))
        outs[chunked] = eng.serve(queue)
    assert set(outs[True]) == set(outs[False]) == {0, 1, 2}
    for r in outs[True]:
        np.testing.assert_array_equal(outs[True][r], outs[False][r])


def test_paged_and_lanes_agree_on_a_dense_arch():
    """Same arch served via both layouts -> identical greedy tokens (the
    pool is an addressing change, not a numeric one)."""
    cfg = get_config("qwen2-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), MAX_SEQ)
    outs = {}
    for layout in ("paged", "lanes"):
        q = synthetic_requests(3, PLEN, cfg.vocab, MAX_NEW, seed=7)
        eng = BatchedServeEngine(model, params, BatchConfig(
            max_seq=MAX_SEQ, n_slots=2, segment_len=2, page_size=4,
            kv_layout=layout,
        ))
        outs[layout] = eng.serve(q)
    for r in outs["paged"]:
        np.testing.assert_array_equal(outs["paged"][r], outs["lanes"][r])
