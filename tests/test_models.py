"""Per-arch smoke + consistency tests.

Every assigned architecture instantiates its REDUCED config (same structure,
small sizes), runs one forward/train step on CPU, asserts shapes and
finiteness, and checks the prefill -> decode path agrees with the parallel
forward pass (the core serving invariant).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build_model, media_spec, needs_media
from repro.optim import AdamW
from repro.train import init_train_state, make_train_step

ALL_ARCHS = sorted(ARCHS)


def _setup(arch, no_drop=False):
    cfg = get_config(arch).reduced()
    if no_drop and cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=100.0)
    model = build_model(cfg)
    params = model.init(jax.random.key(0), 64)
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    media = None
    if needs_media(cfg):
        media = jax.random.normal(
            jax.random.key(2), media_spec(cfg, B, jnp.float32).shape
        )
    return cfg, model, params, tokens, media


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_shapes_and_finiteness(arch):
    cfg, model, params, tokens, media = _setup(arch)
    batch = {"tokens": tokens, "labels": tokens}
    if media is not None:
        batch["media"] = media
    loss = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    logits = (model.forward(params, tokens, media) if media is not None
              else model.forward(params, tokens))
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_one_train_step(arch):
    cfg, model, params, tokens, media = _setup(arch)
    opt = AdamW(lr=1e-3)
    state = init_train_state(model, opt, jax.random.key(0), 64,
                             n_hot_experts=2 if cfg.n_experts else 0)
    step = make_train_step(model, opt, microbatches=1)
    batch = {"tokens": tokens, "labels": tokens}
    if media is not None:
        batch["media"] = media
    state, metrics = step(state, batch)
    assert int(state.step) == 1
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_consistency(arch):
    """decode(prefill(prompt)) logits == parallel forward logits."""
    cfg, model, params, tokens, media = _setup(arch, no_drop=True)
    B, S = tokens.shape
    kw = {"media": media} if media is not None else {}
    full = (model.forward(params, tokens, media) if media is not None
            else model.forward(params, tokens))
    logits_pre, cache = model.prefill(params, tokens[:, : S - 1], 64, **kw)
    lg_dec, _ = model.decode_step(
        params, cache, tokens[:, S - 1], jnp.full((B,), S - 1, jnp.int32)
    )
    np.testing.assert_allclose(np.asarray(logits_pre), np.asarray(full[:, S - 2]),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(full[:, S - 1]),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_chunked_prefill_equals_oneshot(arch):
    cfg, model, params, tokens, media = _setup(arch, no_drop=True)
    B, S, C = tokens.shape[0], tokens.shape[1], 16
    kw = {"media": media} if media is not None else {}
    lg_ref, _ = model.prefill(params, tokens, 64, **kw)
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        jax.eval_shape(lambda: model.init_cache(B, 64, jnp.float32)),
    )
    _, cache = model.chunk_prefill(params, cache, tokens[:, :C], 0, media=media)
    lg, _ = model.chunk_prefill(params, cache, tokens[:, C:], C, media=media)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_multi_step_decode(arch):
    """8 sequential decode steps stay finite and match teacher forcing."""
    cfg, model, params, tokens, media = _setup(arch, no_drop=True)
    B, S = tokens.shape
    kw = {"media": media} if media is not None else {}
    half = S // 2
    full = (model.forward(params, tokens, media) if media is not None
            else model.forward(params, tokens))
    _, cache = model.prefill(params, tokens[:, :half], 64, **kw)
    for t in range(half, min(half + 8, S)):
        lg, cache = model.decode_step(
            params, cache, tokens[:, t], jnp.full((B,), t, jnp.int32)
        )
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, t]),
                                   atol=1e-4, rtol=1e-4)


def test_scan_unroll_equivalence():
    for arch in ("qwen2-7b", "zamba2-2.7b", "whisper-medium"):
        cfg = get_config(arch).reduced()
        m1, m2 = build_model(cfg), build_model(cfg, unroll=True)
        params = m1.init(jax.random.key(0), 32)
        tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
        media = None
        if needs_media(cfg):
            media = jax.random.normal(
                jax.random.key(2), media_spec(cfg, 2, jnp.float32).shape
            )
            o1, o2 = m1.forward(params, tokens, media), m2.forward(params, tokens, media)
        else:
            o1, o2 = m1.forward(params, tokens), m2.forward(params, tokens)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   atol=2e-5, rtol=2e-5)


def test_param_counts_match_analytic():
    """Analytic param_count (used for MODEL_FLOPS) matches actual trees."""
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        model = build_model(cfg)
        abstract = jax.eval_shape(
            lambda k: model.init(k, 128), jax.ShapeDtypeStruct((2,), jnp.uint32)
        )
        actual = sum(np.prod(l.shape) for l in jax.tree.leaves(abstract))
        expected = cfg.param_count()
        if cfg.learned_pos:  # pos tables sized by runtime max_seq, excluded
            expected = expected - cfg.max_position * cfg.d_model + 128 * cfg.d_model
        assert abs(actual - expected) / expected < 0.02, (
            arch, actual, expected)
