"""Fig. 3 regression gate: the ADAPTIVE policy must be no slower than the
best fixed path at the paper's calibration points, and the fixed paths must
stay on the paper's numbers (≈2.6 / 5.1 / 3.4 / 3.5 µs endpoints).

This pins the headline result against policy/monitor refactors: the
decision code under test is the REAL routing module (the same one the
serve engines run), not a simulator-private reimplementation."""
import jax
import pytest

from repro.configs import FIG3_CLAIMS, PAPER_WORKLOAD
from repro.core.monitor import ExactMonitor
from repro.core.policy import AlwaysOffload, AlwaysUnload, FrequencyPolicy, HintPolicy
from repro.core.simulator import sweep_point

N_WRITES, WARMUP = 60_000, 6_000
R_LO, R_HI = 1, 2 ** 20  # the paper's x-axis endpoints


def _avg(policy, n_regions, monitor=None, seed=0):
    avg, _ = sweep_point(jax.random.key(seed), n_regions, N_WRITES, WARMUP,
                         policy, monitor)
    return avg


def _adaptive(n_regions):
    """The paper's evaluation policy: offload the top-4096 heavy hitters."""
    hot = jax.numpy.zeros((n_regions,), bool)
    hot = hot.at[: min(PAPER_WORKLOAD.adaptive_top_k, n_regions)].set(True)
    return HintPolicy(hot_regions=hot)


@pytest.fixture(scope="module")
def endpoints():
    """One simulator pass per (policy, endpoint) — shared by every check."""
    out = {}
    for r in (R_LO, R_HI):
        out[r] = {
            "offload": _avg(AlwaysOffload(), r),
            "unload": _avg(AlwaysUnload(), r),
            "adaptive_hint": _avg(_adaptive(r), r),
            "adaptive_freq": _avg(
                FrequencyPolicy(monitor=ExactMonitor(n_regions=r),
                                threshold=3),
                r, ExactMonitor(n_regions=r)),
        }
    return out


def test_fixed_paths_sit_on_the_paper_calibration(endpoints):
    assert abs(endpoints[R_LO]["offload"]
               - FIG3_CLAIMS["offload_rtt_1_region"]) < 0.1
    assert abs(endpoints[R_HI]["offload"]
               - FIG3_CLAIMS["offload_rtt_2e20_regions"]) < 0.3
    assert abs(endpoints[R_LO]["unload"]
               - FIG3_CLAIMS["unload_rtt_flat"]) < 0.2
    assert abs(endpoints[R_HI]["unload"]
               - FIG3_CLAIMS["unload_rtt_2e20_regions"]) < 0.2


@pytest.mark.parametrize("variant", ["adaptive_hint", "adaptive_freq"])
def test_adaptive_no_slower_than_best_fixed_path_at_endpoints(
        endpoints, variant):
    """The paper's core claim at the calibration endpoints: adaptive tracks
    the better of offload/unload (small tolerance for the monitor's
    warm-up transient)."""
    for r in (R_LO, R_HI):
        best = min(endpoints[r]["offload"], endpoints[r]["unload"])
        assert endpoints[r][variant] <= best + 0.15, (
            r, variant, endpoints[r][variant], best)


def test_adaptive_tracks_paper_endpoint_values(endpoints):
    """Absolute anchor: ~2.6 µs where offload wins (all-hit MTT), ~3.5 µs
    where unload wins (2^20 regions)."""
    assert abs(endpoints[R_LO]["adaptive_hint"]
               - FIG3_CLAIMS["offload_rtt_1_region"]) < 0.15
    assert abs(endpoints[R_HI]["adaptive_hint"]
               - FIG3_CLAIMS["unload_rtt_2e20_regions"]) < 0.25
