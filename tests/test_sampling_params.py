"""Per-request SamplingParams in the jitted scan: slots with DIFFERENT
temperatures / seeds / filters decoding in one batch must be
bit-identical to the same requests run sequentially — batching (and
chunked prefill) is a throughput optimization, never a sampling change —
and the new sampler must reproduce the legacy greedy/sampled engines
exactly at the equivalent settings."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import RequestQueue, synthetic_requests
from repro.models import build_model
from repro.models.sampling import SamplingParams, sample_tokens
from repro.serve import BatchConfig, BatchedServeEngine, Engine, EngineConfig

MIXED = [SamplingParams(max_tokens=8, temperature=0.0, seed=11),
         SamplingParams(max_tokens=8, temperature=1.3, seed=5,
                        top_k=24, top_p=0.9),
         SamplingParams(max_tokens=6, temperature=0.7, seed=7)]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), 64)
    return cfg, model, params


def _engine(model, params, n_slots, **kw):
    kw.setdefault("max_seq", 40)
    kw.setdefault("segment_len", 4)
    kw.setdefault("page_size", 4)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return BatchedServeEngine(model, params, BatchConfig(
            n_slots=n_slots, **kw))


def _queue(cfg, params, plens=(12, 7, 9), n=3):
    return synthetic_requests(n, list(plens), cfg.vocab, 8, seed=3,
                              params=params)


@pytest.mark.parametrize("chunked", [False, True])
def test_mixed_params_batch_equals_sequential(setup, chunked):
    """THE acceptance property: two+ slots with different temperatures
    and seeds in one batch == the same requests run sequentially (one
    slot), across blocking and chunked scheduling."""
    cfg, model, params = setup
    kw = dict(chunked=chunked, chunk_size=3) if chunked else {}
    out_b = _engine(model, params, 2, **kw).serve(_queue(cfg, MIXED))
    out_s = _engine(model, params, 1).serve(_queue(cfg, MIXED))
    assert set(out_b) == set(out_s) == {0, 1, 2}
    for r in out_b:
        np.testing.assert_array_equal(out_b[r], out_s[r])
    # explicit seeds: the stream is a function of the request params
    # alone, so the same prompt+params resubmitted ALONE (fresh queue,
    # different req id) reproduces it too
    q = _queue(cfg, MIXED)
    for r in sorted(out_b):
        solo = RequestQueue()
        req = q.pop()
        solo.submit(req.prompt, params=req.params)
        out_1 = _engine(model, params, 2).serve(solo)
        np.testing.assert_array_equal(out_b[r], out_1[0])


@pytest.mark.parametrize("mode", ["staged", "adaptive"])
def test_mixed_params_hold_across_write_paths(setup, mode):
    """Per-request sampling composes with the unload machinery: the
    staged/adaptive paths carry the same per-slot params through the
    ring overlay, still bit-identical to sequential."""
    cfg, model, params = setup
    out_b = _engine(model, params, 2, write_mode=mode,
                    hot_threshold=3).serve(_queue(cfg, MIXED))
    out_s = _engine(model, params, 1, write_mode=mode,
                    hot_threshold=3).serve(_queue(cfg, MIXED))
    for r in out_b:
        np.testing.assert_array_equal(out_b[r], out_s[r])


def test_mixed_params_on_the_lanes_layout():
    """The lanes layout (SWA/SSM/... families) shares the scan step, so
    per-request params apply there too — batch == sequential."""
    cfg = get_config("h2o-danube-3-4b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), 40)
    out_b = _engine(model, params, 2).serve(_queue(cfg, MIXED))
    eng = _engine(model, params, 1)
    assert eng.layout == "lanes"
    out_s = eng.serve(_queue(cfg, MIXED))
    for r in out_b:
        np.testing.assert_array_equal(out_b[r], out_s[r])


def test_temperature_zero_matches_legacy_greedy(setup):
    cfg, model, params = setup
    p0 = SamplingParams(max_tokens=8, temperature=0.0)
    out_new = _engine(model, params, 2).serve(_queue(cfg, p0))
    out_old = _engine(model, params, 2, greedy=True).serve(
        _queue(cfg, None))
    for r in out_new:
        np.testing.assert_array_equal(out_new[r], out_old[r])


def test_temperature_one_matches_legacy_sampled(setup):
    """temperature=1, top_k=0, top_p=1, seed=None must be bit-identical
    to the legacy ``greedy=False`` engine (same fold_in key derivation,
    same categorical over unfiltered logits)."""
    cfg, model, params = setup
    p1 = SamplingParams(max_tokens=8, temperature=1.0)
    out_new = _engine(model, params, 2).serve(_queue(cfg, p1))
    out_old = _engine(model, params, 2, greedy=False).serve(
        _queue(cfg, None))
    for r in out_new:
        np.testing.assert_array_equal(out_new[r], out_old[r])


def test_top_k_one_is_greedy(setup):
    cfg, model, params = setup
    pk = SamplingParams(max_tokens=8, temperature=1.0, top_k=1, seed=2)
    out_k = _engine(model, params, 2).serve(_queue(cfg, pk))
    out_g = _engine(model, params, 2).serve(
        _queue(cfg, SamplingParams(max_tokens=8, temperature=0.0)))
    for r in out_k:
        np.testing.assert_array_equal(out_k[r], out_g[r])


def test_stop_token_ids_retire_like_eos(setup):
    cfg, model, params = setup
    base = _engine(model, params, 2).serve(_queue(cfg, None))
    stop = int(base[0][3])  # a token the greedy stream emits mid-stream
    out_p = _engine(model, params, 2).serve(_queue(
        cfg, SamplingParams(max_tokens=8, stop_token_ids=(stop,))))
    out_e = _engine(model, params, 2, eos_id=stop).serve(_queue(cfg, None))
    assert set(out_p) == set(out_e)
    for r in out_p:
        np.testing.assert_array_equal(out_p[r], out_e[r])
    assert len(out_p[0]) <= 4 and out_p[0][-1] == stop


def test_per_request_max_tokens(setup):
    cfg, model, params = setup
    plist = [SamplingParams(max_tokens=n) for n in (3, 8, 5)]
    out = _engine(model, params, 2).serve(_queue(cfg, plist))
    assert [len(out[r]) for r in sorted(out)] == [3, 8, 5]


def test_completions_carry_params_and_telemetry(setup):
    cfg, model, params = setup
    eng = Engine.from_config(EngineConfig(
        max_seq=40, n_slots=2, segment_len=4, page_size=4,
        path="adaptive", hot_threshold=2), model, params)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, size=9) for _ in range(3)]
    comps = eng.generate(prompts, MIXED)
    assert [c.req_id for c in comps] == [0, 1, 2]
    for c, p in zip(comps, MIXED):
        assert c.params.temperature == p.temperature
        assert c.n_tokens <= p.max_tokens
        assert c.finish_reason in ("stop", "length")
        assert c.ttft_s >= 0.0
        # every decode write was routed somewhere; prefill rows counted
        assert c.path_counts["direct"] + c.path_counts["staged"] \
            == c.n_tokens - 1
        assert c.path_counts["prefill"] == 9
    # streaming yields the same tokens incrementally
    events = list(eng.stream(prompts, MIXED))
    acc = {}
    for ev in events:
        acc.setdefault(ev.req_id, []).extend(ev.tokens.tolist())
        if ev.done:
            np.testing.assert_array_equal(
                np.asarray(acc[ev.req_id], np.int32),
                ev.completion.tokens)
    for c in comps:
        np.testing.assert_array_equal(
            np.asarray(acc[c.req_id], np.int32), c.tokens)


def test_sampler_filters_shape_the_distribution():
    """Unit-level: top_k/top_p actually truncate support; disabled
    filters reproduce jax.random.categorical bit-for-bit."""
    from repro.models.sampling import SlotParams
    key = jax.random.key(0)
    logits = jax.random.normal(jax.random.key(1), (2, 64))
    kd = jax.random.key_data(jnp.stack([key, jax.random.key(9)]))
    # disabled filters == legacy categorical on the same split schedule
    sp = SlotParams(temperature=jnp.ones((2,)), top_k=jnp.zeros((2,), jnp.int32),
                    top_p=jnp.ones((2,)), stop=jnp.full((2, 4), -1, jnp.int32))
    toks, kd2 = sample_tokens(logits, kd, sp)
    pairs = jax.vmap(jax.random.split)(jax.random.wrap_key_data(kd))
    ref = jax.vmap(jax.random.categorical)(pairs[:, 0], logits)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(ref))
    np.testing.assert_array_equal(
        np.asarray(kd2), np.asarray(jax.random.key_data(pairs[:, 1])))
    # top_k=2: only ever the two largest logits, whatever the draw
    sp2 = SlotParams(temperature=jnp.ones((2,)),
                     top_k=jnp.full((2,), 2, jnp.int32),
                     top_p=jnp.ones((2,)), stop=jnp.full((2, 4), -1, jnp.int32))
    allowed = np.argsort(np.asarray(logits), axis=-1)[:, -2:]
    kd_i = kd
    for _ in range(20):
        t, kd_i = sample_tokens(logits, kd_i, sp2)
        for row in range(2):
            assert int(t[row]) in allowed[row]
    # top_p tiny: collapses to argmax
    sp3 = SlotParams(temperature=jnp.ones((2,)), top_k=jnp.zeros((2,), jnp.int32),
                     top_p=jnp.full((2,), 1e-6), stop=jnp.full((2, 4), -1, jnp.int32))
    t3, _ = sample_tokens(logits, kd, sp3)
    np.testing.assert_array_equal(
        np.asarray(t3), np.asarray(jnp.argmax(logits, axis=-1)))


def test_engine_default_params_backfill(setup):
    """EngineConfig.default_params applies to requests without params,
    and its temperature backfills a request whose own temperature is
    unset — requests that set one keep it."""
    cfg, model, params = setup
    eng = Engine.from_config(EngineConfig(
        max_seq=40, n_slots=2, segment_len=4, page_size=4,
        default_params=SamplingParams(temperature=1.0, max_tokens=6)),
        model, params)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, size=9) for _ in range(3)]
    comps = eng.generate(prompts, [
        None,                                       # engine default
        SamplingParams(max_tokens=4),               # temp backfilled
        SamplingParams(max_tokens=4, temperature=0.0),
    ])
    assert [c.params.temperature for c in comps] == [1.0, 1.0, 0.0]
    assert [c.n_tokens for c in comps] == [6, 4, 4]


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(max_tokens=0)
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.5)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(stop_token_ids=(1, 2, 3, 4))
