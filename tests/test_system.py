"""End-to-end system behaviour: the paper's full loop on a live model.

Train an MoE model with ADAPTIVE dispatch (monitor-driven hot mask), then
serve it with ADAPTIVE KV writes — the complete uRDMA story: one
application-facing interface, two execution paths, runtime routing.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, Pipeline, SyntheticSource
from repro.models import build_model
from repro.optim import AdamW
from repro.serve import ServeConfig, ServeEngine
from repro.train import Trainer, TrainerConfig, init_train_state, make_train_step


def test_end_to_end_adaptive_moe_train_then_serve():
    cfg = get_config("granite-moe-3b-a800m").reduced()
    model = build_model(cfg, dispatch_mode="adaptive")
    opt = AdamW(lr=1e-3)
    n_hot = 2
    state = init_train_state(model, opt, jax.random.key(0), 48,
                             n_hot_experts=n_hot)
    step = jax.jit(make_train_step(model, opt, microbatches=2,
                                   n_hot_experts=n_hot))
    dc = DataConfig(seq_len=32, global_batch=4, vocab=cfg.vocab)
    tr = Trainer(step, state, Pipeline(SyntheticSource(dc)),
                 TrainerConfig(total_steps=8, log_every=100))
    res = tr.run()
    assert res["steps"] == 8
    assert np.isfinite(res["final_loss"])
    # the monitor saw every routed assignment:
    # steps x tokens x top_k x layers
    expected = 8 * (4 * 32) * cfg.top_k * cfg.n_layers
    assert int(jnp.sum(tr.state.expert_counts)) == expected

    # serve the trained weights with adaptive KV writes
    dense_serve = build_model(cfg, dispatch_mode="staged")
    eng = ServeEngine(dense_serve, tr.state.params, ServeConfig(
        max_seq=48, write_mode="direct"))
    toks = eng.generate(jnp.ones((2, 8), jnp.int32), 6)
    assert toks.shape == (2, 6)


def test_end_to_end_dense_serve_paths_agree():
    """Same weights, all three write modes -> identical generations."""
    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), 64)
    prompt = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab)
    outs = []
    for mode in ("direct", "staged", "adaptive"):
        eng = ServeEngine(model, params, ServeConfig(
            max_seq=64, write_mode=mode, ring_size=4, page_size=8))
        outs.append(np.asarray(eng.generate(prompt, 10)))
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])
