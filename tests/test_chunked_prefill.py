"""Chunked-prefill mixed-phase scheduling: chunking must change WHEN
tokens appear (admission is immediate, prefill interleaves with decode),
never WHICH — every comparison here is EXACT token equality against the
admission-blocking engine, across write modes, chunk sizes, sampling
modes, and retirement paths. Plus the per-phase routing split: prefill
chunk writes are bulk/offload by decision-plane decree, decode writes
keep their mode's routing."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import synthetic_requests
from repro.models import build_model
from repro.serve import BatchConfig, BatchedServeEngine

N_REQ, MAX_NEW = 5, 8
PLENS = [20, 6, 11]  # mixed long/short, ragged against every chunk size


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), 64)
    return cfg, model, params


def _queue(cfg, plens=PLENS, max_new=MAX_NEW, n=N_REQ):
    return synthetic_requests(n, plens, cfg.vocab, max_new, seed=3)


def _engine(model, params, chunked, **kw):
    kw.setdefault("segment_len", 4)
    kw.setdefault("ring_size", 4)
    kw.setdefault("hot_threshold", 3)
    kw.setdefault("chunk_size", 3)
    return BatchedServeEngine(model, params, BatchConfig(
        max_seq=40, n_slots=2, page_size=4, chunked=chunked, **kw))


def _assert_same(out_a, out_b):
    assert set(out_a) == set(out_b)
    for r in out_a:
        np.testing.assert_array_equal(out_a[r], out_b[r])


@pytest.mark.parametrize("mode", ["direct", "staged", "adaptive"])
def test_chunked_equals_blocking_every_write_mode(setup, mode):
    cfg, model, params = setup
    out_c = _engine(model, params, True, write_mode=mode).serve(_queue(cfg))
    out_b = _engine(model, params, False, write_mode=mode).serve(_queue(cfg))
    _assert_same(out_c, out_b)
    # and against sequential decode (the acceptance oracle)
    eng1 = BatchedServeEngine(model, params, BatchConfig(
        max_seq=40, n_slots=1, page_size=4, segment_len=4, ring_size=4,
        hot_threshold=3, write_mode=mode))
    _assert_same(out_c, eng1.serve(_queue(cfg)))


@pytest.mark.parametrize("chunk_size", [1, 3, 8])
def test_chunk_size_is_invisible(setup, chunk_size):
    """Any chunking of the prompt produces the same stream (including
    chunk_size=1: pure token-at-a-time prefill)."""
    cfg, model, params = setup
    out_c = _engine(model, params, True,
                    chunk_size=chunk_size).serve(_queue(cfg))
    out_b = _engine(model, params, False).serve(_queue(cfg))
    _assert_same(out_c, out_b)


def test_sampled_streams_survive_chunking(setup):
    """Prefill steps must consume no PRNG splits: the per-request sampled
    stream is a function of the request id alone, chunked or not."""
    cfg, model, params = setup
    out_c = _engine(model, params, True, greedy=False).serve(_queue(cfg))
    out_b = _engine(model, params, False, greedy=False).serve(_queue(cfg))
    _assert_same(out_c, out_b)


def test_eos_and_budget_retirement_through_chunked(setup):
    cfg, model, params = setup
    base = _engine(model, params, False).serve(_queue(cfg))
    eos = int(base[0][3])  # a token the greedy stream emits mid-sequence
    out_c = _engine(model, params, True, eos_id=eos).serve(_queue(cfg))
    out_b = _engine(model, params, False, eos_id=eos).serve(_queue(cfg))
    _assert_same(out_c, out_b)
    assert len(out_c[0]) <= 4 and out_c[0][-1] == eos


def test_max_new_one_emits_in_scan(setup):
    """max_new=1: the only emitted token is the prefill flip's argmax —
    the slot retires without a single decode write."""
    cfg, model, params = setup
    eng = _engine(model, params, True)
    out = eng.serve(_queue(cfg, max_new=1))
    _assert_same(out, _engine(model, params, False).serve(
        _queue(cfg, max_new=1)))
    assert all(out[r].shape == (1,) for r in out)
    assert eng.stats["direct_writes"] == 0
    assert eng.stats["prefill_writes"] == sum(
        PLENS[i % len(PLENS)] for i in range(N_REQ))


def test_per_phase_write_split(setup):
    """Decode writes tally direct/staged by routing; prefill chunk rows
    tally separately (the bulk/offload path) — phase-tagged WriteBatch."""
    cfg, model, params = setup
    eng = _engine(model, params, True, write_mode="staged")
    eng.serve(_queue(cfg))
    n_prompt = sum(PLENS[i % len(PLENS)] for i in range(N_REQ))
    assert eng.stats["prefill_writes"] == n_prompt
    # staged mode stages every SCATTERED write; bulk prefill never stages
    assert eng.stats["staged_writes"] == N_REQ * (MAX_NEW - 1)
    assert eng.stats["direct_writes"] == 0


def test_ttft_is_recorded_for_every_request(setup):
    cfg, model, params = setup
    for chunked in (False, True):
        eng = _engine(model, params, chunked)
        out = eng.serve(_queue(cfg))
        assert set(eng.ttft) == set(out)
        assert all(t >= 0.0 for t in eng.ttft.values())


def test_lanes_layout_chunk_prefills_at_admission(setup):
    """SWA serves from lanes: chunked=True runs model.chunk_prefill at
    admission — same outputs as whole-prompt prefill."""
    cfg = get_config("h2o-danube-3-4b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), 32)
    mk = lambda: synthetic_requests(  # noqa: E731
        3, [11, 5], cfg.vocab, 5, seed=7)
    out_c = BatchedServeEngine(model, params, BatchConfig(
        max_seq=32, n_slots=2, segment_len=2, page_size=4,
        chunked=True, chunk_size=4)).serve(mk())
    eng = BatchedServeEngine(model, params, BatchConfig(
        max_seq=32, n_slots=2, segment_len=2, page_size=4))
    assert eng.layout == "lanes"
    _assert_same(out_c, eng.serve(mk()))
