"""Sharding-rule tests on an ABSTRACT 16x16 / 2x16x16 mesh (no devices
needed): every param/cache spec must divide its dimensions, and the per-arch
attention schemes must match the divisibility table in DESIGN.md."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, get_shape
from repro.distributed.sharding import (
    attention_scheme,
    cache_pspec,
    make_abstract_mesh,
    param_pspec,
    tree_paths_and_leaves,
)
from repro.models import abstract_params, build_model

MESH = make_abstract_mesh((16, 16), ("data", "model"))
MESH3 = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _axis_size(mesh, spec_entry):
    if spec_entry is None:
        return 1
    axes = (spec_entry,) if isinstance(spec_entry, str) else spec_entry
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _check_divisible(mesh, spec, shape, ctx):
    for dim, entry in zip(shape, spec):
        assert dim % _axis_size(mesh, entry) == 0, (ctx, shape, spec)


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("mesh", [MESH, MESH3], ids=["single", "multipod"])
def test_param_specs_divide_shapes(arch, mesh):
    cfg = get_config(arch)
    shape = get_shape("train_4k")
    aparams = abstract_params(cfg, shape)
    for path, leaf in tree_paths_and_leaves(aparams):
        spec = param_pspec(cfg, mesh, path, leaf.shape)
        assert len(spec) <= len(leaf.shape)
        _check_divisible(mesh, spec, leaf.shape, f"{arch}:{path}")


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_cache_specs_divide_shapes(arch):
    cfg = get_config(arch)
    model = build_model(cfg)
    for shape_name, batch in (("decode_32k", 128), ("long_500k", 1)):
        shape = get_shape(shape_name)
        if shape_name == "long_500k" and not cfg.subquadratic:
            continue
        cache = jax.eval_shape(
            lambda: model.init_cache(batch, shape.seq_len, jnp.bfloat16)
        )
        for path, leaf in tree_paths_and_leaves(cache):
            spec = cache_pspec(cfg, MESH, path, leaf.shape)
            _check_divisible(MESH, spec, leaf.shape, f"{arch}:{path}")


def test_attention_schemes_match_design_table():
    """DESIGN.md's divisibility-driven scheme table, enforced."""
    expected = {
        "nemotron-4-15b": "qheads_kvrepl",   # 48%16=0, kv 8%16!=0
        "h2o-danube-3-4b": "qheads_kvrepl",  # 32%16=0, kv 8
        "qwen2-7b": "headdim",               # 28 heads, Dh=128
        "stablelm-1.6b": "heads",            # 32/32
        "granite-moe-3b-a800m": "headdim",   # 24 heads, Dh=64
        "qwen3-moe-235b-a22b": "qheads_kvrepl",  # 64, kv 4
        "mamba2-130m": "none",               # attention-free
        "llama-3.2-vision-90b": "qheads_kvrepl",  # 64, kv 8
        "whisper-medium": "heads",           # 16/16
        "zamba2-2.7b": "heads",              # 32/32
    }
    for arch, want in expected.items():
        got = attention_scheme(get_config(arch), MESH)
        assert got == want, (arch, got, want)


def test_lookup_table_never_vocab_sharded():
    for arch in sorted(ARCHS):
        cfg = get_config(arch)
        spec = param_pspec(cfg, MESH, "embed/tok", (cfg.vocab, cfg.d_model))
        assert spec[0] is None, (arch, spec)  # gather stays local


def test_long_500k_cache_seq_sharded():
    cfg = get_config("zamba2-2.7b")
    spec = cache_pspec(cfg, MESH, "k", (9, 1, 524288, 32, 80))
    # B=1: sequence must shard over every axis
    assert spec[2] == ("data", "model")
