"""KV-cache write-path tests: staged ring overlay == direct writes, paged
pool bookkeeping, drain via the Pallas kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kvcache import (
    BlockPool,
    add_ring,
    drain_ring,
    gather_view,
    logical_to_physical,
    make_paged_kv,
    maybe_drain,
    pool_rows,
    scatter_token,
    strip_ring,
    view_mask,
    view_rows,
)
from repro.models import build_model


@pytest.mark.parametrize("arch", ["qwen2-7b", "h2o-danube-3-4b"])
def test_staged_ring_decode_equals_direct(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.key(0), 64)
    B, S, STEPS = 2, 24, 8
    tokens = jax.random.randint(jax.random.key(1), (B, S + STEPS), 0, cfg.vocab)

    _, cache_d = m.prefill(params, tokens[:, :S], 64)
    cd = cache_d
    for t in range(STEPS):
        lg_d, cd = m.decode_step(params, cd, tokens[:, S + t],
                                 jnp.full((B,), S + t, jnp.int32))

    _, cache_s = m.prefill(params, tokens[:, :S], 64)
    cs = add_ring(cache_s, 4)
    for t in range(STEPS):
        lg_s, cs = m.decode_step(params, cs, tokens[:, S + t],
                                 jnp.full((B,), S + t, jnp.int32))
        cs, _ = maybe_drain(cs)

    np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_d),
                               atol=1e-4, rtol=1e-4)
    cs = drain_ring(cs, use_kernel=False)
    np.testing.assert_allclose(np.asarray(cs["k"]), np.asarray(cd["k"]),
                               atol=1e-5, rtol=1e-5)


def test_adaptive_mixed_paths_match_direct():
    cfg = get_config("stablelm-1.6b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.key(0), 64)
    B, S, STEPS = 4, 16, 6
    tokens = jax.random.randint(jax.random.key(1), (B, S + STEPS), 0, cfg.vocab)
    full = m.forward(params, tokens)
    _, cache = m.prefill(params, tokens[:, :S], 64)
    cs = add_ring(cache, 4)
    mask = jnp.asarray([False, True, False, True])  # per-sequence routing
    for t in range(STEPS):
        lg, cs = m.decode_step(params, cs, tokens[:, S + t],
                               jnp.full((B,), S + t, jnp.int32),
                               unload_mask=mask)
        cs, _ = maybe_drain(cs)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, S + STEPS - 1]),
                               atol=1e-4, rtol=1e-4)


def test_drain_with_kernel_matches_reference_drain():
    cfg = get_config("stablelm-1.6b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.key(0), 64)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.key(1), (B, S + 4), 0, cfg.vocab)
    _, cache = m.prefill(params, tokens[:, :S], 64)
    cs = add_ring(cache, 4)
    for t in range(4):
        _, cs = m.decode_step(params, cs, tokens[:, S + t],
                              jnp.full((B,), S + t, jnp.int32))
    a = drain_ring(cs, use_kernel=True)
    b = drain_ring(cs, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a["k"], np.float32),
                               np.asarray(b["k"], np.float32), atol=1e-6)


def test_strip_ring_removes_overlay():
    cfg = get_config("stablelm-1.6b").reduced()
    m = build_model(cfg)
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        jax.eval_shape(lambda: m.init_cache(2, 32, jnp.float32)),
    )
    ringed = add_ring(cache, 4)
    assert "ring_k" in ringed
    assert set(strip_ring(ringed)) == set(cache)


# ---------------------------------------------------------------------------
# paged pool
# ---------------------------------------------------------------------------


def test_paged_pool_insert_gather_roundtrip():
    """Token tiles written through the physical mapping come back, in
    logical order, through the gathered per-slot view."""
    pool = BlockPool(16)
    cache = make_paged_kv(n_layers=1, n_blocks=16, page_size=4, n_slots=3,
                          max_pages=4, h=2, dh=8)
    table = np.full((3, 4), -1, np.int32)
    for s in range(3):
        table[s, :3] = pool.alloc(s, 3)  # 10 rows -> 3 pages of 4
    cache["page_table"] = jnp.asarray(table)
    rng = np.random.RandomState(0)
    ref = np.zeros((3, 16, 2, 8), np.float32)
    for t in range(10):
        k = jnp.asarray(rng.randn(3, 2, 8), jnp.float32)
        dest = logical_to_physical(cache, jnp.full((3,), t, jnp.int32))
        cache["pages_k"] = cache["pages_k"].at[0].set(
            scatter_token(cache["pages_k"][0], dest, k))
        ref[:, t] = np.asarray(k)
    vm = view_mask(cache, jnp.full((3,), 9, jnp.int32))
    assert vm.tolist()[0] == [True] * 10 + [False] * 2 + [False] * 4
    kk = gather_view(cache["pages_k"][0], view_rows(cache))
    for b in range(3):
        np.testing.assert_allclose(np.asarray(kk[b, :10]), ref[b, :10],
                                   atol=1e-6)


def test_paged_destination_mapping_and_write_masking():
    pool = BlockPool(8)
    cache = make_paged_kv(n_layers=1, n_blocks=8, page_size=4, n_slots=2,
                          max_pages=4, h=1, dh=4)
    table = np.full((2, 4), -1, np.int32)
    table[0, 0] = pool.alloc(0, 1)[0]
    table[1, 0] = pool.alloc(1, 1)[0]
    cache["page_table"] = jnp.asarray(table)
    dest = logical_to_physical(cache, jnp.asarray([0, 0], jnp.int32))
    assert dest[0] != dest[1]                      # own block each
    assert (dest // 4).tolist() == [table[0, 0], table[1, 0]]
    # sentinel rows (retired slot / unallocated page) resolve out of range
    dead = logical_to_physical(cache, jnp.asarray([-1, 4], jnp.int32))
    assert dead.tolist() == [pool_rows(cache)] * 2
    before = np.asarray(cache["pages_k"][0])
    cache["pages_k"] = cache["pages_k"].at[0].set(scatter_token(
        cache["pages_k"][0], dead, jnp.ones((2, 1, 4), jnp.float32)))
    np.testing.assert_array_equal(np.asarray(cache["pages_k"][0]), before)
