"""KV-cache write-path tests: staged ring overlay == direct writes, paged
pool bookkeeping, drain via the Pallas kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kvcache import (
    add_ring,
    allocate_pages,
    direct_insert,
    drain_ring,
    gather_kv,
    make_paged_cache,
    maybe_drain,
    strip_ring,
    write_destination,
)
from repro.models import build_model


@pytest.mark.parametrize("arch", ["qwen2-7b", "h2o-danube-3-4b"])
def test_staged_ring_decode_equals_direct(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.key(0), 64)
    B, S, STEPS = 2, 24, 8
    tokens = jax.random.randint(jax.random.key(1), (B, S + STEPS), 0, cfg.vocab)

    _, cache_d = m.prefill(params, tokens[:, :S], 64)
    cd = cache_d
    for t in range(STEPS):
        lg_d, cd = m.decode_step(params, cd, tokens[:, S + t],
                                 jnp.full((B,), S + t, jnp.int32))

    _, cache_s = m.prefill(params, tokens[:, :S], 64)
    cs = add_ring(cache_s, 4)
    for t in range(STEPS):
        lg_s, cs = m.decode_step(params, cs, tokens[:, S + t],
                                 jnp.full((B,), S + t, jnp.int32))
        cs, _ = maybe_drain(cs)

    np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_d),
                               atol=1e-4, rtol=1e-4)
    cs = drain_ring(cs, use_kernel=False)
    np.testing.assert_allclose(np.asarray(cs["k"]), np.asarray(cd["k"]),
                               atol=1e-5, rtol=1e-5)


def test_adaptive_mixed_paths_match_direct():
    cfg = get_config("stablelm-1.6b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.key(0), 64)
    B, S, STEPS = 4, 16, 6
    tokens = jax.random.randint(jax.random.key(1), (B, S + STEPS), 0, cfg.vocab)
    full = m.forward(params, tokens)
    _, cache = m.prefill(params, tokens[:, :S], 64)
    cs = add_ring(cache, 4)
    mask = jnp.asarray([False, True, False, True])  # per-sequence routing
    for t in range(STEPS):
        lg, cs = m.decode_step(params, cs, tokens[:, S + t],
                               jnp.full((B,), S + t, jnp.int32),
                               unload_mask=mask)
        cs, _ = maybe_drain(cs)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, S + STEPS - 1]),
                               atol=1e-4, rtol=1e-4)


def test_drain_with_kernel_matches_reference_drain():
    cfg = get_config("stablelm-1.6b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.key(0), 64)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.key(1), (B, S + 4), 0, cfg.vocab)
    _, cache = m.prefill(params, tokens[:, :S], 64)
    cs = add_ring(cache, 4)
    for t in range(4):
        _, cs = m.decode_step(params, cs, tokens[:, S + t],
                              jnp.full((B,), S + t, jnp.int32))
    a = drain_ring(cs, use_kernel=True)
    b = drain_ring(cs, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a["k"], np.float32),
                               np.asarray(b["k"], np.float32), atol=1e-6)


def test_strip_ring_removes_overlay():
    cfg = get_config("stablelm-1.6b").reduced()
    m = build_model(cfg)
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        jax.eval_shape(lambda: m.init_cache(2, 32, jnp.float32)),
    )
    ringed = add_ring(cache, 4)
    assert "ring_k" in ringed
    assert set(strip_ring(ringed)) == set(cache)


# ---------------------------------------------------------------------------
# paged pool
# ---------------------------------------------------------------------------


def test_paged_cache_alloc_insert_gather():
    cache = make_paged_cache(n_pages=16, page_size=4, h=2, dh=8, batch=3,
                             max_pages_per_seq=4)
    rng = np.random.RandomState(0)
    seqs = jnp.asarray([0, 1, 2], jnp.int32)
    ref = np.zeros((3, 16, 2, 8), np.float32)
    for t in range(10):
        cache = allocate_pages(cache, seqs)
        k = jnp.asarray(rng.randn(3, 2, 8), jnp.float32)
        v = jnp.asarray(rng.randn(3, 2, 8), jnp.float32)
        cache = direct_insert(cache, seqs, k, v)
        ref[:, t] = np.asarray(k)
    assert cache.lengths.tolist() == [10, 10, 10]
    assert int(cache.n_allocated) == 9  # ceil(10/4)=3 pages x 3 seqs
    for b in range(3):
        kk, vv, valid = gather_kv(cache, jnp.asarray(b), 16)
        assert valid.tolist() == [True] * 10 + [False] * 6
        np.testing.assert_allclose(np.asarray(kk[:10]), ref[b, :10], atol=1e-6)


def test_write_destination_page_mapping():
    cache = make_paged_cache(n_pages=8, page_size=4, h=1, dh=4, batch=2,
                             max_pages_per_seq=4)
    seqs = jnp.asarray([0, 1], jnp.int32)
    cache = allocate_pages(cache, seqs)
    page, row = write_destination(cache, seqs)
    assert row.tolist() == [0, 0]
    assert page[0] != page[1]  # each sequence got its own page
