"""Roofline tooling unit tests: HLO collective parser, affine combination,
scan-vs-unroll cost accounting assumptions."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.launch.roofline import (
    _combine,
    _shape_bytes,
    collective_bytes,
    roofline_terms,
)


def test_shape_bytes():
    assert _shape_bytes("f32", "128,256") == 128 * 256 * 4
    assert _shape_bytes("bf16", "16") == 32
    assert _shape_bytes("pred", "8,8") == 64
    assert _shape_bytes("s32", "") == 4  # scalar


def test_collective_parser_counts_ops():
    hlo = """
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), replica_groups={}
  %ag.1 = bf16[64,128]{1,0} all-gather(bf16[4,128]{1,0} %y), dimensions={0}
  %rs = f32[8]{0} reduce-scatter(f32[128]{0} %z), dimensions={0}
  %cp = f32[256]{0} collective-permute(f32[256]{0} %w)
  %notacoll = f32[9]{0} add(f32[9]{0} %a, f32[9]{0} %b)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 4096
    assert out["all-gather"] == 64 * 128 * 2  # max shape on the line
    assert out["reduce-scatter"] == 128 * 4
    assert out["collective-permute"] == 1024
    assert out["total"] == sum(
        v for k, v in out.items() if k != "total"
    )


def test_collective_parser_skips_done_ops():
    hlo = """
  %s = f32[64]{0} all-reduce-start(f32[64]{0} %x)
  %d = f32[64]{0} all-reduce-done(f32[64]{0} %s)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 256  # start counted once, done skipped


def test_affine_combine():
    a = {"flops": 10.0, "bytes": 4.0}
    b = {"flops": 6.0, "coll": 2.0}
    out = _combine(a, b, 2.0, 3.0)
    assert out["flops"] == 2 * 10 + 3 * 6
    assert out["bytes"] == 8.0
    assert out["coll"] == 6.0


def test_roofline_terms_dominance():
    from repro.configs import get_shape

    cfg = get_config("stablelm-1.6b")
    shape = get_shape("train_4k")
    m = {"flops": 197e12, "bytes": 819e9 * 10, "coll_bytes": 50e9}
    t = roofline_terms(m, cfg, shape)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(10.0)
    assert t["collective_s"] == pytest.approx(1.0)
    assert t["dominant"] == "memory"
    assert t["roofline_fraction"] == pytest.approx(0.1)


def test_scan_undercounts_unroll_doesnt():
    """The methodology premise: cost_analysis counts a while body once."""
    from jax import lax

    def f_scan(x, w):
        return lax.scan(lambda c, wi: (jnp.tanh(c @ wi), None), x, w)[0]

    def f_unroll(x, w):
        for i in range(4):
            x = jnp.tanh(x @ w[i])
        return x

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    from repro.compat import cost_analysis_dict

    fs = cost_analysis_dict(jax.jit(f_scan).lower(x, w).compile())["flops"]
    fu = cost_analysis_dict(jax.jit(f_unroll).lower(x, w).compile())["flops"]
    assert fu > 3 * fs  # unrolled sees ~4x the flops


def test_depth_probe_configs_preserve_structure():
    from repro.launch.cells import depth_probes, full_depth_units, probe_config

    for arch in ("qwen2-7b", "llama-3.2-vision-90b", "zamba2-2.7b",
                 "whisper-medium", "mamba2-130m"):
        cfg = get_config(arch)
        for _, kw, _ in depth_probes(cfg):
            pc = probe_config(cfg, kw)
            assert pc.family == cfg.family
            assert pc.d_model == cfg.d_model
            if cfg.family == "vlm":
                assert pc.n_layers % pc.cross_attn_every == 0
            if cfg.family == "hybrid":
                assert pc.n_layers % pc.hybrid_attn_every == 0
        units = full_depth_units(cfg)
        assert units == (cfg.n_layers, cfg.n_enc_layers) \
            if cfg.family == "encdec" else units >= 1
