"""Training loop: microbatched train_step factory + fault-tolerant Trainer.

train_step design
-----------------
* gradient accumulation: the global batch splits into M microbatches scanned
  sequentially with an fp32 grad accumulator — this is what bounds MoE
  staging-buffer and activation memory at the assigned global batch sizes;
* remat: per-layer activation checkpointing inside the model (scan-of-layers
  + jax.checkpoint), policy via the model's ``remat`` flag;
* MoE monitor: the expert-load counters accumulated during the step update
  ``TrainState.expert_counts``, and the NEXT step's adaptive hot-mask is
  derived between steps (paper: thresholds recalibrated off the critical
  path);
* everything is a pure function (state, batch) -> (state, metrics): pjit
  shards it with the rules in ``repro.distributed.sharding``.

Trainer (host loop) fault tolerance
-----------------------------------
* checkpoint every N steps (async, atomic) + resume-from-latest;
* straggler detection: EWMA of step wall time; steps slower than
  ``straggler_factor``x the EWMA are logged and counted (on real fleets this
  signal feeds the scheduler; here it feeds tests);
* crash-retry: a failing step (transient host OOM / preemption in real
  deployments, injected fault in tests) is retried from the last known-good
  state up to ``max_retries`` times.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.decision import expert_hot_mask
from ..optim.adamw import AdamW, AdamWState

log = logging.getLogger("repro.train")


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jnp.ndarray                       # int32
    expert_counts: Optional[jnp.ndarray]    # int32 [E] (MoE) | None
    hot_mask: Optional[jnp.ndarray]         # bool [E] (MoE adaptive) | None


def init_train_state(model, optimizer: AdamW, key, max_seq: int,
                     n_hot_experts: int = 0) -> TrainState:
    params = model.init(key, max_seq)
    cfg = model.cfg
    is_moe = getattr(cfg, "n_experts", 0) > 0
    counts = jnp.zeros((cfg.n_experts,), jnp.int32) if is_moe else None
    hot = (
        jnp.zeros((cfg.n_experts,), jnp.bool_).at[:max(n_hot_experts, 1)].set(True)
        if (is_moe and n_hot_experts) else None
    )
    return TrainState(params, optimizer.init(params), jnp.zeros((), jnp.int32),
                      counts, hot)


def make_train_step(
    model,
    optimizer: AdamW,
    *,
    microbatches: int = 1,
    remat: bool = True,
    n_hot_experts: int = 0,
    unroll_accum: bool = False,
) -> Callable[[TrainState, Dict[str, jnp.ndarray]], Tuple[TrainState, Dict]]:
    """Build the jit-able (state, batch) -> (state, metrics) step.

    ``unroll_accum``: python-loop the grad-accum microbatches instead of
    lax.scan — used by the roofline prober (cost_analysis counts a scanned
    body once)."""
    is_moe = getattr(model.cfg, "n_experts", 0) > 0

    def loss_fn(params, mb, hot_mask):
        if is_moe:
            loss, loads = model.loss_with_stats(params, mb, remat=remat,
                                                hot_mask=hot_mask)
            return loss, jnp.sum(loads, axis=0)  # [E]
        return model.loss(params, mb, remat=remat), jnp.zeros((0,), jnp.int32)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def split_mb(batch):
        def split(a):
            b = a.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return a.reshape((microbatches, b // microbatches) + a.shape[1:])
        return jax.tree.map(split, batch)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        hot = state.hot_mask

        if microbatches == 1:
            (loss, loads), grads = grad_fn(state.params, batch, hot)
        else:
            mbs = split_mb(batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            l0 = jnp.zeros((), jnp.float32)
            e0 = jnp.zeros(
                (model.cfg.n_experts if is_moe else 0,), jnp.int32
            )

            def acc_body(carry, mb):
                g_acc, l_acc, e_acc = carry
                (l, e), g = grad_fn(state.params, mb, hot)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + l, e_acc + e), None

            if unroll_accum:
                from ..models.scan import python_scan

                (grads, loss, loads), _ = python_scan(acc_body, (g0, l0, e0), mbs)
            else:
                (grads, loss, loads), _ = jax.lax.scan(
                    acc_body, (g0, l0, e0), mbs
                )
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches

        new_params, new_opt, om = optimizer.update(grads, state.opt, state.params)

        counts = state.expert_counts
        new_hot = state.hot_mask
        if is_moe and counts is not None:
            counts = counts + loads
            if n_hot_experts:
                # paper §3.2: recalibrate the hot set off the critical path
                new_hot = expert_hot_mask(counts, n_hot_experts)

        metrics = {"loss": loss, **om, "step": state.step + 1}
        return (
            TrainState(new_params, new_opt, state.step + 1, counts, new_hot),
            metrics,
        )

    return train_step


# ---------------------------------------------------------------------------
# Host-side Trainer with fault tolerance
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    straggler_factor: float = 3.0
    straggler_warmup: int = 5
    max_retries: int = 2
    log_every: int = 10


class Trainer:
    def __init__(self, train_step, state: TrainState, pipeline, cfg: TrainerConfig,
                 put_batch=None):
        self.train_step = train_step
        self.state = state
        self.pipeline = pipeline
        self.cfg = cfg
        self.put_batch = put_batch or (lambda b: jax.tree.map(jnp.asarray, b))
        self.ewma_ms: Optional[float] = None
        self.stragglers = 0
        self.retries = 0
        self._ckpt_thread = None
        self.history: list = []

    # -- fault tolerance ----------------------------------------------------
    def maybe_resume(self):
        from .. import checkpoint as ckpt

        if not self.cfg.checkpoint_dir:
            return
        step = ckpt.latest_step(self.cfg.checkpoint_dir)
        if step is None:
            return
        log.info("resuming from checkpoint step %d", step)
        self.state = ckpt.restore(self.cfg.checkpoint_dir, self.state, step)
        self.pipeline.skip_to(int(step))

    def _checkpoint(self, step: int):
        from .. import checkpoint as ckpt

        if not self.cfg.checkpoint_dir:
            return
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()  # never queue more than one write
        self._ckpt_thread = ckpt.save_async(self.cfg.checkpoint_dir, step, self.state)
        ckpt.prune(self.cfg.checkpoint_dir, self.cfg.keep_checkpoints)

    # -- loop ----------------------------------------------------------------
    def run(self, fault_hook: Optional[Callable[[int], None]] = None) -> Dict:
        """fault_hook(step): test hook that may raise to simulate failures."""
        start = int(self.state.step)
        for step in range(start, self.cfg.total_steps):
            batch = self.put_batch(next(self.pipeline))
            for attempt in range(self.cfg.max_retries + 1):
                try:
                    if fault_hook is not None:
                        fault_hook(step)
                    t0 = time.perf_counter()
                    self.state, metrics = self.train_step(self.state, batch)
                    jax.block_until_ready(metrics["loss"])
                    dt_ms = (time.perf_counter() - t0) * 1e3
                    break
                except Exception as e:  # noqa: BLE001 — retry transient faults
                    self.retries += 1
                    log.warning("step %d attempt %d failed: %s", step, attempt, e)
                    if attempt == self.cfg.max_retries:
                        raise
            # straggler detection (EWMA of step time); the first step is
            # compile-dominated and would poison the baseline — skip it
            if step == start:
                pass
            elif self.ewma_ms is None:
                self.ewma_ms = dt_ms
            else:
                if (step - start) > self.cfg.straggler_warmup and dt_ms > (
                    self.cfg.straggler_factor * self.ewma_ms
                ):
                    self.stragglers += 1
                    log.warning(
                        "straggler step %d: %.1fms vs EWMA %.1fms",
                        step, dt_ms, self.ewma_ms,
                    )
                self.ewma_ms = 0.9 * self.ewma_ms + 0.1 * dt_ms

            self.history.append(float(metrics["loss"]))
            if step % self.cfg.log_every == 0:
                log.info("step %d loss %.4f (%.0f ms)", step,
                         float(metrics["loss"]), dt_ms)
            if self.cfg.checkpoint_dir and (step + 1) % self.cfg.checkpoint_every == 0:
                self._checkpoint(step + 1)

        if self._ckpt_thread is not None:
            self._ckpt_thread.join()
        return {
            "final_loss": self.history[-1] if self.history else float("nan"),
            "stragglers": self.stragglers,
            "retries": self.retries,
            "steps": len(self.history),
        }
