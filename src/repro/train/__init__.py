from .trainer import (
    Trainer,
    TrainerConfig,
    TrainState,
    init_train_state,
    make_train_step,
)

__all__ = [
    "Trainer",
    "TrainerConfig",
    "TrainState",
    "init_train_state",
    "make_train_step",
]
