"""Continuous-batching serve scheduler over the paged KV pool.

The repo's serving layer decoded one request (batch) at a time; this module
turns it into a slot-based continuous-batching system — the setting where
the paper's decision machinery actually earns its keep: a fixed array of
serving SLOTS decodes in lock-step inside ONE jitted ``lax.scan``, each
slot at its own position in its own request, so the DecisionModule sees a
genuinely interleaved multi-tenant write stream (per-slot destination
blocks in a SHARED physical pool) instead of a single flow.

Architecture (DESIGN.md §4–§5):

* **SlotState** — per-slot phase / token / position / done-flag /
  remaining-budget / sample-key / request-id / prompt-length, all
  fixed-shape int/bool arrays living in the scan carry. Retirement is
  IN-scan: a slot whose emitted token hits EOS or whose budget is spent
  flips ``done`` and from the next step neither writes KV (its physical
  destination resolves to the drop sentinel) nor updates the
  page-frequency monitor.
* **Mixed-phase segments** (``chunked=True``, paged layout) — prompts are
  NOT prefilled at admission: a request is admitted immediately with
  ``phase=PREFILL`` and a chunk cursor at 0, its prompt parked in a padded
  device-side buffer. Inside the scan each slot processes a
  [chunk_size]-token slab per step — prefill slots consume the next prompt
  chunk, decode slots their single sampled token — and a slot flips
  PREFILL→DECODE in-scan when its cursor crosses the prompt length
  (emitting its first token from the last prompt position's logits).
  Prefill writes are bulk/contiguous and phase-tagged ``PHASE_BULK`` so
  the decision plane pins them to the offload path; scattered decode
  writes stay adaptive. This dissolves the host-side prefill
  serialization: long prompts no longer stall the other slots' decode.
* **Admission** — BETWEEN scan segments, on the host: the FIFO
  ``RequestQueue`` is scanned in submission order and a request that does
  not fit (``BlockPool`` can't cover its next allocation) is SKIPPED in
  favor of later ones that do — it keeps its queue position and is
  admitted as soon as blocks free up, so relative order among
  admissible-when-eligible requests is preserved (no head-of-line
  blocking). With ``chunked=True`` block allocation is per-chunk: a slot
  holds only the pages the NEXT segment can touch, topped up between
  segments (a long prompt never reserves its whole footprint at
  admission; a slot whose top-up fails simply stalls for one segment).
* **KV writes** — every decode-time write resolves through the page table
  to a physical pool row; direct writes scatter straight in, staged writes
  ride the per-slot ring overlay and drain in bulk through
  ``core.ring.scatter_rows``. The monitor's region universe is the
  physical BLOCK id.

Two cache layouts:

* ``paged``  — dense non-SWA DecoderLM family: the paged pool + ring
  overlay (all three write modes, in-scan chunked prefill).
* ``lanes``  — every other family (SSM / hybrid / MoE / enc-dec / VLM /
  SWA): the model's own cache pytree with batch = n_slots; admission
  overwrites a retired slot's lane wholesale (every cache leaf carries
  batch on axis 1 — the repo-wide convention). Direct mode only, same
  scheduler machinery. ``chunked=True`` here runs the prompt through
  ``model.chunk_prefill`` chunk-by-chunk at admission (host side, same
  chunk size, bit-identical to whole-prompt prefill) — the in-scan mixed
  phase needs the paged pool's row addressing.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core.paths import build_decision, resolve_attention
from ..core.types import PHASE_BULK, PHASE_SCATTERED, make_write_batch
from ..data.pipeline import RequestQueue
from ..kvcache import paged as PG
from ..models import sampling as SMP
from ..models.sampling import SamplingParams, SlotParams
from ..models.transformer import DecoderLM, direct_kv_write

# Slot phases (values of SlotState.phase). DONE is not a phase: the `done`
# flag retires a slot out of both phases.
PHASE_PREFILL = 0
PHASE_DECODE = 1


def paged_capable(model) -> bool:
    """Can this model serve from the paged pool? Linear-addressed dense
    ``DecoderLM`` only: SWA's ring addressing IS its window bound, and the
    VLM grouped scan lacks the mask plumbing (DESIGN.md §Arch-applicability)."""
    return (isinstance(model, DecoderLM)
            and not model.is_vlm
            and not model.cfg.sliding_window)


class SlotState(NamedTuple):
    """Fixed slot array — the whole scheduler state inside the scan carry.

    phase:     int32[S] PHASE_PREFILL (consuming prompt chunks) or
               PHASE_DECODE (sampling); meaningful only while not done
    token:     int32[S] last emitted token (next decode step's input)
    pos:       int32[S] next logical row to write: the chunk cursor while
               prefilling, the decode position afterwards
    done:      bool[S]  retired (or never admitted) — inactive slots
    remaining: int32[S] tokens the slot may still emit
    key:       uint32[S, 2] per-slot PRNG key data (sampled decode)
    req_id:    int32[S] owning request id (-1 = empty)
    plen:      int32[S] prompt length (the PREFILL→DECODE flip point)

    Per-request sampling parameters (``repro.models.sampling``) ride in
    the same carry so every decode step samples each slot under its own
    request's knobs:

    temperature: f32[S]; top_k: i32[S]; top_p: f32[S];
    stop: i32[S, MAX_STOP_TOKENS] stop-token table (-1 padded, includes
    the engine eos_id)
    """

    phase: jnp.ndarray
    token: jnp.ndarray
    pos: jnp.ndarray
    done: jnp.ndarray
    remaining: jnp.ndarray
    key: jnp.ndarray
    req_id: jnp.ndarray
    plen: jnp.ndarray
    temperature: jnp.ndarray
    top_k: jnp.ndarray
    top_p: jnp.ndarray
    stop: jnp.ndarray

    @property
    def sampling(self) -> SlotParams:
        return SlotParams(temperature=self.temperature, top_k=self.top_k,
                          top_p=self.top_p, stop=self.stop)


def make_slots(n_slots: int) -> SlotState:
    sp = SMP.make_slot_params(n_slots)
    return SlotState(
        phase=jnp.full((n_slots,), PHASE_DECODE, jnp.int32),
        token=jnp.zeros((n_slots,), jnp.int32),
        pos=jnp.zeros((n_slots,), jnp.int32),
        done=jnp.ones((n_slots,), jnp.bool_),
        remaining=jnp.zeros((n_slots,), jnp.int32),
        key=jnp.zeros((n_slots, 2), jnp.uint32),
        req_id=jnp.full((n_slots,), -1, jnp.int32),
        plen=jnp.zeros((n_slots,), jnp.int32),
        temperature=sp.temperature,
        top_k=sp.top_k,
        top_p=sp.top_p,
        stop=sp.stop,
    )


@dataclasses.dataclass
class BatchConfig:
    """Continuous-batching engine configuration.

    ``max_seq`` bounds prompt_len + max_new per request; ``n_blocks = 0``
    sizes the pool for zero contention (n_slots * pages-per-slot).
    ``chunked`` admits prompts immediately and prefills them in
    ``chunk_size``-token chunks inside the decode scan (paged layout; the
    lanes layout chunk-prefills at admission instead).

    ``path`` / ``policy`` name a registered ``repro.core.paths.WritePath``
    and ``RoutingPolicy`` (capability-negotiated at construction);
    ``write_mode`` is the legacy alias — the built-in path names coincide
    with the old mode strings, and ``path`` wins when both are set.
    ``default_params`` supplies engine-wide ``SamplingParams`` defaults
    for requests that carry none; ``greedy`` is the legacy temperature
    default (0.0 when True, 1.0 when False) for params that leave
    ``temperature`` unset.

    ``attention`` picks the paged read implementation: ``"fused"`` (the
    ``flash_decode_paged`` kernel: page-table walk + ring overlay + SDPA
    in one pass), ``"reference"`` (jnp gather + concat — the kernel's
    parity oracle), or ``"auto"`` (negotiated through
    ``core.paths.resolve_attention``: fused wherever the kernel compiles
    natively, reference on CPU). ``drain_kernel=None`` likewise
    auto-selects the ``staged_scatter`` drain kernel (on by default
    off-CPU; ``REPRO_DRAIN_KERNEL`` overrides).
    """

    max_seq: int
    n_slots: int = 8
    segment_len: int = 16
    write_mode: str = "direct"
    page_size: int = 8
    n_blocks: int = 0
    ring_size: int = 8
    hot_threshold: int = 4
    greedy: bool = True
    eos_id: Optional[int] = None
    drain_kernel: Optional[bool] = None
    attention: str = "auto"      # auto | fused | reference
    kv_layout: str = "auto"      # auto | paged | lanes
    sample_seed: int = 0
    chunked: bool = False
    chunk_size: int = 8
    path: Optional[str] = None
    policy: Optional[str] = None
    default_params: Optional[SamplingParams] = None


class BatchedServeEngine:
    """Slot-based continuous-batching serving engine.

    >>> eng = BatchedServeEngine(model, params, BatchConfig(max_seq=128))
    >>> outputs = eng.serve(queue)          # {req_id: np.ndarray tokens}
    """

    def __init__(self, model, params, cfg: BatchConfig, _warn: bool = True):
        if _warn:
            warnings.warn(
                "constructing BatchedServeEngine directly is deprecated; "
                "use repro.serve.Engine.from_config(...) — the shim stays "
                "for one release",
                DeprecationWarning, stacklevel=2)
        self.model = model
        self.params = params
        self.cfg = cfg

        layout = cfg.kv_layout
        if layout == "auto":
            layout = "paged" if paged_capable(model) else "lanes"
        if layout == "paged" and not paged_capable(model):
            raise ValueError(
                f"paged KV serves the linear-addressed dense family; "
                f"{model.cfg.name} needs kv_layout='lanes'"
            )
        if cfg.chunked and cfg.chunk_size < 1:
            raise ValueError("chunk_size must be >= 1")
        self.layout = layout

        ps = cfg.page_size
        self.max_pages = -(-cfg.max_seq // ps)
        self.n_blocks = cfg.n_blocks or cfg.n_slots * self.max_pages
        # region universe: physical pool blocks (paged) or per-slot pages
        # (lanes) — either way the monitor sees the interleaved stream
        n_regions = (self.n_blocks if layout == "paged"
                     else cfg.n_slots * self.max_pages)
        # registry-driven decision plane: resolve the (path, policy) names,
        # negotiate capabilities against the layout/scheduling (loud error
        # on e.g. lanes + a staged-capable path)
        self.path, self.decision = build_decision(
            cfg.path or cfg.write_mode, cfg.policy, n_regions=n_regions,
            hot_threshold=cfg.hot_threshold, layout=layout,
            chunked=cfg.chunked)
        self.uses_ring = self.path.uses_ring
        self.mon_state = self.decision.init_state()
        # negotiated read-side implementation (fused kernel vs jnp
        # reference), resolved ONCE like the write path above
        self.attention = resolve_attention(
            cfg.attention, layout=layout,
            arch_paged_capable=paged_capable(model))

        if layout == "paged":
            shape = jax.eval_shape(lambda: model.init_cache(1, cfg.max_seq))
            l, _, _, h, dh = shape["k"].shape
            self.pool = PG.BlockPool(self.n_blocks)
            self.cache = PG.make_paged_kv(
                l, self.n_blocks, ps, cfg.n_slots, self.max_pages, h, dh,
                dtype=shape["k"].dtype,
                ring_size=cfg.ring_size if self.uses_ring else 0,
            )
        else:
            self.pool = None
            self.cache = model.init_cache(cfg.n_slots, cfg.max_seq)
        self.slots = make_slots(cfg.n_slots)
        # device-side prompt buffer for in-scan chunked prefill
        self._in_scan_prefill = cfg.chunked and layout == "paged"
        self.prompts = (jnp.zeros((cfg.n_slots, cfg.max_seq), jnp.int32)
                        if self._in_scan_prefill else None)

        # host-side shadows (device round-trips happen once per segment)
        self._occupied = [False] * cfg.n_slots
        self._slot_req: List[int] = [-1] * cfg.n_slots
        self._slot_plen: List[int] = [0] * cfg.n_slots
        self._slot_max_new: List[int] = [0] * cfg.n_slots
        self._slot_pages: List[int] = [0] * cfg.n_slots
        self._base_key = jax.random.key(cfg.sample_seed)
        self.outputs: Dict[int, List[int]] = {}
        self.ttft: Dict[int, float] = {}
        # per-request telemetry: resolved SamplingParams and write-path
        # counts [direct, staged, prefill] (the Completion payload)
        self.req_params: Dict[int, SamplingParams] = {}
        self.req_writes: Dict[int, np.ndarray] = {}
        self._t_serve0: Optional[float] = None
        self.stats = {
            "direct_writes": 0, "staged_writes": 0, "drains": 0,
            "prefill_writes": 0, "segments": 0, "admitted": 0, "retired": 0,
        }
        # compiled segment variants keyed by STATIC sampler mode
        # (greedy/sampled/filtered — repro.models.sampling); _segment_fn /
        # _mixed_fn hold the last-used variant
        self._segment_fns: Dict[str, Callable] = {}
        self._mixed_fns: Dict[str, Callable] = {}
        self._segment_fn: Optional[Callable] = None
        self._mixed_fn: Optional[Callable] = None
        self._prefill_fns: Dict[Any, Callable] = {}

    def reset(self) -> None:
        """Fresh serving state (cache, slots, pool, monitor, outputs) with
        the compiled segment functions retained — benchmark/test runs can
        re-serve without paying compilation again."""
        cfg = self.cfg
        if self.layout == "paged":
            self.pool = PG.BlockPool(self.n_blocks)
            l, _, ps, h, dh = self.cache["pages_k"].shape
            self.cache = PG.make_paged_kv(
                l, self.n_blocks, ps, cfg.n_slots, self.max_pages, h, dh,
                dtype=self.cache["pages_k"].dtype,
                ring_size=cfg.ring_size if self.uses_ring else 0,
            )
        else:
            self.cache = self.model.init_cache(cfg.n_slots, cfg.max_seq)
        self.slots = make_slots(cfg.n_slots)
        if self._in_scan_prefill:
            self.prompts = jnp.zeros((cfg.n_slots, cfg.max_seq), jnp.int32)
        self.mon_state = self.decision.init_state()
        self._occupied = [False] * cfg.n_slots
        self._slot_req = [-1] * cfg.n_slots
        self._slot_plen = [0] * cfg.n_slots
        self._slot_max_new = [0] * cfg.n_slots
        self._slot_pages = [0] * cfg.n_slots
        self.outputs = {}
        self.ttft = {}
        self.req_params = {}
        self.req_writes = {}
        self._t_serve0 = None
        self.stats = {k: 0 for k in self.stats}

    # ------------------------------------------------------------------
    # segments: the jitted inner loops
    # ------------------------------------------------------------------
    def _build_segment(self, mode: str) -> Callable:
        """Pure-decode segment: every live slot samples one token per step
        (the steady state; also the only segment the non-chunked engine
        runs). ``mode`` statically specializes the sampler to the live
        slots' params (a pure-greedy batch pays exactly the argmax step)."""
        model, cfg = self.model, self.cfg
        paged = self.layout == "paged"
        ring = paged and self.uses_ring
        ps, nb, mp = cfg.page_size, self.n_blocks, self.max_pages
        decision = self.decision
        attn = self.attention

        def step(params, enabled, plan, carry, _):
            cache, st, mon, stats, swrites = carry
            active = ~st.done & enabled
            if paged:
                dest = PG.logical_to_physical(
                    cache, jnp.where(active, st.pos, -1))
                region = jnp.minimum(dest // ps, nb - 1)
            else:
                region = (jnp.arange(cfg.n_slots) * mp
                          + jnp.clip(st.pos // ps, 0, mp - 1))
            unload, mon, _ = decision(
                mon, make_write_batch(region), active=active)
            n_u = jnp.sum(unload.astype(jnp.int32))
            drained = jnp.zeros((), jnp.bool_)
            if ring:
                cache, drained = PG.maybe_drain(
                    cache, use_kernel=cfg.drain_kernel,
                    incoming_pos=jnp.where(active, st.pos, -1))
                logits, cache = model.decode_step_paged(
                    params, cache, st.token, st.pos, active,
                    unload_mask=unload, attention=attn, plan=plan)
            elif paged:
                logits, cache = model.decode_step_paged(
                    params, cache, st.token, st.pos, active,
                    attention=attn, plan=plan)
            else:
                # retired slots never write: redirect their scatter rows
                # to the out-of-range drop sentinel (SSM recurrent state
                # has no KV scatter — its lane updates are slot-private
                # and overwritten wholesale at admission)
                def masked_writer(kc, vc, k_new, v_new, rows):
                    return direct_kv_write(
                        kc, vc, k_new, v_new,
                        jnp.where(active, rows, kc.shape[1]))

                logits, cache = model.decode_step(
                    params, cache, st.token, st.pos, kv_writer=masked_writer)
            # per-request sampling: every slot under its own params, its
            # own key chain (repro.models.sampling contract)
            nxt, key = SMP.sample_tokens(logits, st.key, st.sampling,
                                         mode=mode)
            nxt = jnp.where(active, nxt, st.token)
            remaining = st.remaining - active.astype(jnp.int32)
            ended = (remaining <= 0) | SMP.hits_stop(nxt, st.stop)
            st = st._replace(
                token=nxt,
                pos=st.pos + active.astype(jnp.int32),
                done=st.done | (active & ended),
                remaining=remaining,
                key=key,
            )
            stats = stats + jnp.stack([
                jnp.sum(active.astype(jnp.int32)) - n_u,
                n_u,
                drained.astype(jnp.int32),
                jnp.zeros((), jnp.int32),
            ])
            swrites = swrites + jnp.stack([
                (active & ~unload).astype(jnp.int32),
                unload.astype(jnp.int32),
                jnp.zeros_like(st.pos),
            ], axis=1)
            emit = jnp.where(active, nxt, -1)
            return (cache, st, mon, stats, swrites), (emit, active)

        def run(params, cache, st, mon, enabled):
            # page-table products are segment-invariant (allocation is
            # host-side, between segments): derive them ONCE here, outside
            # the scan, instead of once per step per layer
            plan = PG.step_plan(cache) if paged else None
            stats0 = jnp.zeros((4,), jnp.int32)
            sw0 = jnp.zeros((cfg.n_slots, 3), jnp.int32)
            (cache, st, mon, stats, swrites), (emits, acts) = lax.scan(
                lambda c, x: step(params, enabled, plan, c, x),
                (cache, st, mon, stats0, sw0),
                None,
                length=cfg.segment_len,
            )
            if ring:
                # segment boundary: the host may retire slots and free
                # their blocks next — the ring must not hold entries that
                # would later drain into reallocated blocks
                cache = PG.drain_ring(cache, use_kernel=cfg.drain_kernel)
            return cache, st, mon, stats, swrites, emits, acts

        return jax.jit(run)

    def _build_mixed_segment(self, mode: str) -> Callable:
        """Mixed-phase segment (chunked, paged layout): each step every
        live slot processes a [chunk_size]-token slab — the next prompt
        chunk (PREFILL) or its one decode token (DECODE, column 0) — and a
        slot flips PREFILL→DECODE in-scan when its cursor crosses plen,
        emitting its first token from the last prompt position's logits.
        Prefill writes are phase-tagged PHASE_BULK: the decision plane
        pins them to the offload/direct path; scattered decode writes keep
        adaptive routing."""
        model, cfg = self.model, self.cfg
        ring = self.uses_ring
        ps, nb, c = cfg.page_size, self.n_blocks, cfg.chunk_size
        decision = self.decision
        attn = self.attention

        def step(params, prompts, enabled, plan, carry, _):
            cache, st, mon, stats, swrites = carry
            active = ~st.done & enabled
            is_pf = active & (st.phase == PHASE_PREFILL)
            # token slab: prefill slots read the device prompt buffer at
            # their chunk cursor; decode slots put their token in column 0
            offs = jnp.arange(c, dtype=jnp.int32)[None, :]
            idx = jnp.clip(st.pos[:, None] + offs, 0, prompts.shape[1] - 1)
            pf_toks = jnp.take_along_axis(prompts, idx, axis=1)
            dec_toks = jnp.pad(st.token[:, None], ((0, 0), (0, c - 1)))
            tokens = jnp.where(is_pf[:, None], pf_toks, dec_toks)
            n_valid = jnp.where(is_pf,
                                jnp.minimum(c, st.plen - st.pos),
                                active.astype(jnp.int32))
            qvalid = offs < n_valid[:, None]
            rows = st.pos[:, None] + offs
            # decision plane: ONE flattened phase-tagged batch per step —
            # bulk prefill rows are pinned offload, decode rows adaptive
            dest_all = PG.logical_to_physical_many(
                cache, jnp.where(qvalid, rows, -1))
            region = jnp.minimum(dest_all // ps, nb - 1)
            phase_tag = jnp.where(
                is_pf[:, None] & qvalid, PHASE_BULK, PHASE_SCATTERED)
            unload_flat, mon, _ = decision(
                mon,
                make_write_batch(region.reshape(-1),
                                 phase=phase_tag.reshape(-1)),
                active=qvalid.reshape(-1))
            unload = (unload_flat.reshape(cfg.n_slots, c)[:, 0]
                      & active & ~is_pf)
            n_u = jnp.sum(unload.astype(jnp.int32))
            n_dec = jnp.sum((active & ~is_pf).astype(jnp.int32))
            n_pf = jnp.sum((qvalid & is_pf[:, None]).astype(jnp.int32))
            drained = jnp.zeros((), jnp.bool_)
            if ring:
                cache, drained = PG.maybe_drain(
                    cache, use_kernel=cfg.drain_kernel,
                    incoming_pos=jnp.where(active & ~is_pf, st.pos, -1))
                logits, cache = model.decode_chunk_paged(
                    params, cache, tokens, st.pos, n_valid, active,
                    unload_mask=unload, attention=attn, plan=plan)
            else:
                logits, cache = model.decode_chunk_paged(
                    params, cache, tokens, st.pos, n_valid, active,
                    attention=attn, plan=plan)
            finishing = is_pf & (st.pos + n_valid >= st.plen)
            emitting = (active & ~is_pf) | finishing
            # the first token after the prompt is the prefill ARGMAX in
            # both engines and both sampling modes (parity with the
            # non-chunked engine's admission-time t0)
            t0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            sampled, new_key = SMP.sample_tokens(logits, st.key,
                                                 st.sampling, mode=mode)
            dec = active & ~is_pf
            # prefill steps consume no key: the per-request split
            # sequence stays identical to the non-chunked engine
            nxt = jnp.where(dec, sampled, t0)
            key = jnp.where(dec[:, None], new_key, st.key)
            nxt = jnp.where(emitting, nxt, st.token)
            remaining = st.remaining - emitting.astype(jnp.int32)
            ended = (remaining <= 0) | SMP.hits_stop(nxt, st.stop)
            st = st._replace(
                phase=jnp.where(finishing, PHASE_DECODE, st.phase),
                token=nxt,
                pos=st.pos + n_valid,
                done=st.done | (emitting & ended),
                remaining=remaining,
                key=key,
            )
            stats = stats + jnp.stack(
                [n_dec - n_u, n_u, drained.astype(jnp.int32), n_pf])
            swrites = swrites + jnp.stack([
                (dec & ~unload).astype(jnp.int32),
                unload.astype(jnp.int32),
                jnp.where(is_pf, n_valid, 0),
            ], axis=1)
            emit = jnp.where(emitting, nxt, -1)
            return (cache, st, mon, stats, swrites), (emit, emitting)

        def run(params, cache, st, mon, prompts, enabled):
            # per-segment hoist of page-table products (see _build_segment)
            plan = PG.step_plan(cache)
            stats0 = jnp.zeros((4,), jnp.int32)
            sw0 = jnp.zeros((cfg.n_slots, 3), jnp.int32)
            (cache, st, mon, stats, swrites), (emits, ems) = lax.scan(
                lambda cry, x: step(params, prompts, enabled, plan, cry, x),
                (cache, st, mon, stats0, sw0),
                None,
                length=cfg.segment_len,
            )
            if ring:
                cache = PG.drain_ring(cache, use_kernel=cfg.drain_kernel)
            return cache, st, mon, stats, swrites, emits, ems

        return jax.jit(run)

    # ------------------------------------------------------------------
    # admission / retirement / allocation (host, between segments)
    # ------------------------------------------------------------------
    def _pages_needed(self, plen: int, max_new: int) -> int:
        # decode writes rows plen .. plen+max_new-2 (the final emitted
        # token is never consumed, so its KV is never written)
        return max(1, -(-(plen + max_new - 1) // self.cfg.page_size))

    def _segment_cover_pages(self, pos: int, prefilling: bool,
                             plen: int, max_new: int) -> int:
        """Pages covering the worst-case rows the NEXT segment can write
        for a slot at ``pos`` — THE per-chunk allocation formula, shared by
        admission (`_first_pages`) and between-segment top-up
        (`_topup_blocks`). A prefilling slot advances up to
        ``segment_len * chunk_size`` rows (a mid-segment PREFILL→DECODE
        flip advances strictly less), a decoding slot ``segment_len``;
        both are capped by the footprint ``plen + max_new - 1`` (the final
        emitted token's KV is never written)."""
        cfg = self.cfg
        cap = plen + max_new - 1
        adv = cfg.segment_len * (cfg.chunk_size if prefilling else 1)
        rows = min(pos + adv, max(cap, plen))
        return max(1, -(-rows // cfg.page_size))

    def _first_pages(self, req) -> int:
        """Pages to allocate at admission: the whole footprint
        (non-chunked), or only what the FIRST segment can touch
        (per-chunk granularity)."""
        if not self._in_scan_prefill:
            return self._pages_needed(req.prompt_len, req.max_new)
        return self._segment_cover_pages(0, True, req.prompt_len,
                                         req.max_new)

    def _topup_blocks(self) -> np.ndarray:
        """Per-chunk allocation: before each segment, extend every live
        slot's page table to cover the rows the NEXT segment can write.
        Returns the enabled mask — a slot whose top-up fails (pool
        exhausted) stalls for one segment instead of deadlocking."""
        cfg = self.cfg
        enabled = np.ones((cfg.n_slots,), bool)
        if not self._in_scan_prefill:
            return enabled
        pos = np.asarray(self.slots.pos)
        phase = np.asarray(self.slots.phase)
        done = np.asarray(self.slots.done)
        for s in range(cfg.n_slots):
            if not self._occupied[s] or bool(done[s]):
                continue
            want = self._segment_cover_pages(
                int(pos[s]), phase[s] == PHASE_PREFILL,
                self._slot_plen[s], self._slot_max_new[s])
            have = self._slot_pages[s]
            if want > have:
                got = self.pool.alloc(s, want - have)
                if got is None:
                    enabled[s] = False
                    continue
                self.cache["page_table"] = self.cache["page_table"].at[
                    s, have:want].set(jnp.asarray(got))
                self._slot_pages[s] = want
        return enabled

    def _prefill(self, prompts: jnp.ndarray, max_seq: int, media):
        """Jitted batched prefill, cached per (max_seq, media?) — jit
        re-specializes per (group size, prompt_len) shape on its own.
        Admission batches every same-length prompt into ONE prefill call;
        per-row results are bit-identical to solo prefills, so grouping is
        invisible to the decode stream."""
        key = (max_seq, media is not None)
        fn = self._prefill_fns.get(key)
        if fn is None:
            if media is None:
                fn = jax.jit(
                    lambda p, t: self.model.prefill(p, t, max_seq))
            else:
                fn = jax.jit(
                    lambda p, t, m: self.model.prefill(p, t, max_seq, media=m))
            self._prefill_fns[key] = fn
        args = (self.params, prompts) if media is None else (
            self.params, prompts, media)
        return fn(*args)

    def _chunk_prefill_host(self, prompts: jnp.ndarray, max_seq: int, media):
        """Whole-prompt prefill done in ``chunk_size``-token pieces through
        ``model.chunk_prefill`` (lanes layout under ``chunked=True``).
        Bit-identical to ``model.prefill`` — exercised across every arch by
        the config-matrix parity test. Runs eagerly: chunk boundaries are
        static Python values (ring addressing branches on them), so a jit
        per (chunk, start) pair would buy nothing at admission frequency."""
        g, plen = prompts.shape
        cache = self.model.init_cache(g, max_seq)
        # enc-dec (Whisper): the audio encoder runs on the FIRST chunk and
        # its cross-KV is reused from the cache on later ones; the VLM
        # family's gated cross layers consume media on every chunk
        media_once = hasattr(self.model, "encode")
        logits = None
        for s0 in range(0, plen, self.cfg.chunk_size):
            chunk = prompts[:, s0:s0 + self.cfg.chunk_size]
            m = None if (media_once and s0 > 0) else media
            logits, cache = self.model.chunk_prefill(
                self.params, cache, chunk, s0, media=m)
        return logits, cache

    def _resolve_params(self, req) -> SamplingParams:
        """The request's effective SamplingParams: request > engine
        default > legacy ``greedy`` flag (for an unset temperature)."""
        return SMP.resolve(req.params, self.cfg.default_params,
                           self.cfg.greedy)

    def _admit_sampling(self, slot_arr, reqs, plist) -> dict:
        """Per-slot sampling-state updates for a group admission: the
        resolved param fields and each request's PRNG key (explicit seed
        or the legacy (sample_seed, req_id) derivation). Key derivation
        is ONE vmapped dispatch per admission — per-request Python
        dispatches would dominate a small reduced-model serve pass."""
        keys = jax.random.key_data(jax.vmap(
            lambda i: jax.random.fold_in(self._base_key, i)
        )(jnp.asarray([r.req_id for r in reqs], jnp.int32)))
        seeded = [(i, p.seed) for i, p in enumerate(plist)
                  if p.seed is not None]
        if seeded:
            # explicit seeds are the rare case: per-request derive_key
            # keeps ONE definition of the seed->key mapping (the common
            # unseeded path above stays a single vmapped dispatch)
            rows = jnp.asarray([i for i, _ in seeded], jnp.int32)
            skeys = jnp.stack([SMP.derive_key(self._base_key, 0, s)
                               for _, s in seeded])
            keys = keys.at[rows].set(jax.random.key_data(skeys))
        stop = np.asarray(
            [SMP.stop_table(p, self.cfg.eos_id) for p in plist], np.int32)
        st = self.slots
        return dict(
            key=st.key.at[slot_arr].set(keys),
            temperature=st.temperature.at[slot_arr].set(jnp.asarray(
                [p.temperature for p in plist], jnp.float32)),
            top_k=st.top_k.at[slot_arr].set(jnp.asarray(
                [p.top_k for p in plist], jnp.int32)),
            top_p=st.top_p.at[slot_arr].set(jnp.asarray(
                [p.top_p for p in plist], jnp.float32)),
            stop=st.stop.at[slot_arr].set(jnp.asarray(stop)),
        )

    def _record_first_tokens(self, rids) -> None:
        if self._t_serve0 is None:
            self._t_serve0 = time.perf_counter()
        now = time.perf_counter()
        for rid in rids:
            self.ttft.setdefault(rid, now - self._t_serve0)

    def _admit_chunked(self, slots: List[int], reqs: List[Any],
                       blocks: List[np.ndarray]) -> None:
        """Chunked (paged) admission: NO prefill — park the prompt in the
        device buffer, point the page table at the first per-chunk blocks,
        and hand the slot to the scan in PREFILL phase."""
        cfg = self.cfg
        slot_arr = jnp.asarray(slots, jnp.int32)
        padded = np.zeros((len(reqs), cfg.max_seq), np.int32)
        for i, r in enumerate(reqs):
            padded[i, : r.prompt_len] = r.prompt
        self.prompts = self.prompts.at[slot_arr].set(jnp.asarray(padded))
        table = np.full((len(reqs), self.max_pages), -1, np.int32)
        for i, b in enumerate(blocks):
            table[i, : len(b)] = b
        self.cache["page_table"] = self.cache["page_table"].at[
            slot_arr].set(jnp.asarray(table))
        plist = [self._resolve_params(r) for r in reqs]
        st = self.slots
        self.slots = st._replace(
            phase=st.phase.at[slot_arr].set(PHASE_PREFILL),
            token=st.token.at[slot_arr].set(0),
            pos=st.pos.at[slot_arr].set(0),
            done=st.done.at[slot_arr].set(False),
            remaining=st.remaining.at[slot_arr].set(
                jnp.asarray([p.max_tokens for p in plist], jnp.int32)),
            req_id=st.req_id.at[slot_arr].set(
                jnp.asarray([r.req_id for r in reqs], jnp.int32)),
            plen=st.plen.at[slot_arr].set(
                jnp.asarray([r.prompt_len for r in reqs], jnp.int32)),
            **self._admit_sampling(slot_arr, reqs, plist),
        )
        for slot, req, p, b in zip(slots, reqs, plist, blocks):
            self._occupied[slot] = True
            self._slot_req[slot] = req.req_id
            self._slot_plen[slot] = req.prompt_len
            self._slot_max_new[slot] = p.max_tokens
            self._slot_pages[slot] = len(b)
            self.outputs[req.req_id] = []
            self.req_params[req.req_id] = p
            self.req_writes[req.req_id] = np.zeros((3,), np.int64)
        self.stats["admitted"] += len(reqs)

    def _admit_group(self, slots: List[int], reqs: List[Any],
                     blocks: List[Optional[np.ndarray]]) -> None:
        """Admit a group of same-prompt-length requests with ONE batched
        prefill + ONE insert + ONE slot-state update."""
        cfg = self.cfg
        g, plen = len(reqs), reqs[0].prompt_len
        ps = cfg.page_size
        prompts = jnp.asarray(np.stack([r.prompt for r in reqs]), jnp.int32)
        media = None
        if reqs[0].media is not None:
            media = jnp.asarray(np.stack([r.media for r in reqs]))
        slot_arr = jnp.asarray(slots, jnp.int32)

        if self.layout == "paged":
            logits, pc = self._prefill(prompts, plen, media)
            cache = self.cache
            l, nbp = cache["pages_k"].shape[0], PG.pool_rows(cache)
            rows = np.arange(plen)
            phys = np.concatenate(
                [b[rows // ps] * ps + rows % ps for b in blocks])
            phys = jnp.asarray(phys, jnp.int32)
            for pk, src in (("pages_k", "k"), ("pages_v", "v")):
                flat = cache[pk].reshape((l, nbp) + cache[pk].shape[3:])
                vals = pc[src][:, :, :plen]  # [L, g, plen, H, Dh]
                flat = flat.at[:, phys].set(
                    vals.reshape((l, g * plen) + vals.shape[3:]))
                cache[pk] = flat.reshape(cache[pk].shape)
            padded = np.full((g, self.max_pages), -1, np.int32)
            for i, b in enumerate(blocks):
                padded[i, : len(b)] = b
            cache["page_table"] = cache["page_table"].at[slot_arr].set(
                jnp.asarray(padded))
            regions = np.concatenate([b[rows // ps] for b in blocks])
        else:
            if self.cfg.chunked:
                logits, pc = self._chunk_prefill_host(
                    prompts, cfg.max_seq, media)
            else:
                logits, pc = self._prefill(prompts, cfg.max_seq, media)
            self.cache = jax.tree.map(
                lambda big, small: big.at[:, slot_arr].set(small),
                self.cache, pc,
            )
            regions = np.concatenate([
                s * self.max_pages + np.arange(plen) // ps for s in slots])
        # prefill writes are dense/contiguous -> offload path; they still
        # heat the page counters (the paper's frequency monitor sees every
        # write that lands in a region)
        self.mon_state = self.decision.heat(self.mon_state, regions)

        plist = [self._resolve_params(r) for r in reqs]
        t0s = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        rem = np.asarray([p.max_tokens - 1 for p in plist], np.int32)
        stop_rows = np.asarray(
            [SMP.stop_table(p, cfg.eos_id) for p in plist], np.int32)
        done0 = (rem <= 0) | np.any(stop_rows == t0s[:, None], axis=1)
        st = self.slots
        self.slots = st._replace(
            phase=st.phase.at[slot_arr].set(PHASE_DECODE),
            token=st.token.at[slot_arr].set(jnp.asarray(t0s)),
            pos=st.pos.at[slot_arr].set(plen),
            done=st.done.at[slot_arr].set(jnp.asarray(done0)),
            remaining=st.remaining.at[slot_arr].set(jnp.asarray(rem)),
            req_id=st.req_id.at[slot_arr].set(
                jnp.asarray([r.req_id for r in reqs], jnp.int32)),
            plen=st.plen.at[slot_arr].set(plen),
            **self._admit_sampling(slot_arr, reqs, plist),
        )
        for slot, req, p, t0, b in zip(slots, reqs, plist, t0s, blocks):
            self._occupied[slot] = True
            self._slot_req[slot] = req.req_id
            self._slot_plen[slot] = req.prompt_len
            self._slot_max_new[slot] = p.max_tokens
            self._slot_pages[slot] = 0 if b is None else len(b)
            self.outputs[req.req_id] = [int(t0)]
            self.req_params[req.req_id] = p
            # admission-time prefill rows are bulk/offload writes
            self.req_writes[req.req_id] = np.asarray(
                [0, 0, req.prompt_len], np.int64)
        self._record_first_tokens([r.req_id for r in reqs])
        self.stats["admitted"] += g

    def _retire(self, slots: List[int]) -> None:
        for slot in slots:
            if self.pool is not None:
                self.pool.free_slot(slot)
            self._occupied[slot] = False
            self._slot_req[slot] = -1
            self._slot_plen[slot] = 0
            self._slot_max_new[slot] = 0
            self._slot_pages[slot] = 0
        if self.pool is not None and slots:
            self.cache["page_table"] = self.cache["page_table"].at[
                jnp.asarray(slots, jnp.int32)].set(-1)
        self.stats["retired"] += len(slots)

    def admit(self, queue: RequestQueue) -> int:
        """Admit waiting requests into free slots, scanning the queue in
        submission order. A request whose blocks can't be covered RIGHT NOW
        is skipped in favor of later ones that fit — it keeps its queue
        position and is admitted once blocks free up (completion-order
        fairness without head-of-line blocking). Same-prompt-length
        requests admitted together share one batched prefill. Returns
        #admitted."""
        picks: List[tuple] = []  # (slot, req, blocks)
        free = [s for s in range(self.cfg.n_slots) if not self._occupied[s]]
        qi = 0
        while free and qi < len(queue):
            req = queue.at(qi)
            if req.prompt_len + req.max_new > self.cfg.max_seq:
                raise ValueError(
                    f"request {req.req_id}: prompt_len+max_new "
                    f"{req.prompt_len + req.max_new} > max_seq {self.cfg.max_seq}"
                )
            blocks = None
            if self.pool is not None:
                total = self._pages_needed(req.prompt_len, req.max_new)
                if total > self.pool.n_blocks:
                    raise ValueError(
                        f"request {req.req_id} needs {total} blocks; "
                        f"pool holds {self.pool.n_blocks}")
                blocks = self.pool.alloc(free[0], self._first_pages(req))
                if blocks is None:
                    qi += 1  # doesn't fit now: let later requests try
                    continue
            picks.append((free.pop(0), queue.pop_at(qi), blocks))
        if self._in_scan_prefill:
            if picks:
                self._admit_chunked([p[0] for p in picks],
                                    [p[1] for p in picks],
                                    [p[2] for p in picks])
        else:
            # group same-length prompts into one prefill dispatch each
            groups: Dict[int, List[tuple]] = {}
            for p in picks:
                groups.setdefault(p[1].prompt_len, []).append(p)
            for members in groups.values():
                self._admit_group([m[0] for m in members],
                                  [m[1] for m in members],
                                  [m[2] for m in members])
        return len(picks)

    # ------------------------------------------------------------------
    # the serve loop
    # ------------------------------------------------------------------
    def _mixed_phase_pending(self) -> bool:
        """Does the NEXT segment need the mixed-phase step? Only when a
        live slot is still prefilling — phases only flip PREFILL→DECODE
        inside a segment, so a pure-decode start stays pure."""
        if not self._in_scan_prefill:
            return False
        phase = np.asarray(self.slots.phase)
        done = np.asarray(self.slots.done)
        return bool(np.any(~done & (phase == PHASE_PREFILL)
                           & np.asarray(self._occupied)))

    def run_segment(self, enabled: Optional[np.ndarray] = None) -> np.ndarray:
        """One jitted scan segment + ONE host readback. Returns the bool
        [segment_len, n_slots] emission matrix (which steps emitted).
        ``enabled`` (bool[n_slots], optional) stalls slots whose per-chunk
        block top-up failed."""
        if enabled is None:
            enabled = np.ones((self.cfg.n_slots,), bool)
        enabled_j = jnp.asarray(enabled)
        # static sampler specialization: the cheapest variant covering
        # the OCCUPANTS' params (a slot forced into a richer variant than
        # its own params need produces identical tokens — the variants
        # differ only in traced work, never in results)
        mode = SMP.required_mode(
            [self.req_params[self._slot_req[s]]
             for s in range(self.cfg.n_slots) if self._occupied[s]])
        if self._mixed_phase_pending():
            self._mixed_fn = self._mixed_fns.get(mode)
            if self._mixed_fn is None:
                self._mixed_fn = self._build_mixed_segment(mode)
                self._mixed_fns[mode] = self._mixed_fn
            (self.cache, self.slots, self.mon_state, stats, swrites,
             emits, acts) = (
                self._mixed_fn(self.params, self.cache, self.slots,
                               self.mon_state, self.prompts, enabled_j))
        else:
            self._segment_fn = self._segment_fns.get(mode)
            if self._segment_fn is None:
                self._segment_fn = self._build_segment(mode)
                self._segment_fns[mode] = self._segment_fn
            (self.cache, self.slots, self.mon_state, stats, swrites,
             emits, acts) = (
                self._segment_fn(self.params, self.cache, self.slots,
                                 self.mon_state, enabled_j))
        emits, acts = np.asarray(emits), np.asarray(acts)
        swrites = np.asarray(swrites)
        d, s, dr, pf = (int(x) for x in stats)
        self.stats["direct_writes"] += d
        self.stats["staged_writes"] += s
        self.stats["drains"] += dr
        self.stats["prefill_writes"] += pf
        self.stats["segments"] += 1
        first = []
        for slot in range(self.cfg.n_slots):
            if self._occupied[slot]:
                rid = self._slot_req[slot]
                self.req_writes[rid] += swrites[slot]
                toks = emits[acts[:, slot], slot]
                if len(toks):
                    if not self.outputs[rid]:
                        first.append(rid)
                    self.outputs[rid].extend(int(t) for t in toks)
        if first:
            self._record_first_tokens(first)
        return acts

    def retire_done(self) -> int:
        """Free every occupied-but-done slot (host, between segments)."""
        done = np.asarray(self.slots.done)
        retiring = [s for s in range(self.cfg.n_slots)
                    if self._occupied[s] and bool(done[s])]
        self._retire(retiring)
        return len(retiring)

    def serve(self, queue: RequestQueue,
              max_segments: int = 100_000) -> Dict[int, np.ndarray]:
        """Drain the queue to completion: admit / scan a segment / collect /
        retire, until no request is live. Returns {req_id: tokens}."""
        if self._t_serve0 is None:
            self._t_serve0 = time.perf_counter()
        for _ in range(max_segments):
            self.retire_done()
            self.admit(queue)
            if not any(self._occupied):
                # admit() marks every admitted slot occupied, so an empty
                # engine here means nothing was admittable
                if len(queue) == 0:
                    break
                raise RuntimeError(
                    "queue head unadmittable with an empty engine "
                    "(request larger than pool capacity?)")
            # all-done slot arrays would make the segment a no-op: only
            # scan when at least one slot is live
            live = ~np.asarray(self.slots.done) & np.asarray(self._occupied)
            if not live.any():
                continue
            enabled = self._topup_blocks()
            if not (live & enabled).any():
                raise RuntimeError(
                    "every live slot stalled on block top-up: the pool is "
                    "too small for the admitted working set")
            self.run_segment(enabled)
        else:
            raise RuntimeError(f"serve() exceeded {max_segments} segments")
        return {rid: np.asarray(t, np.int32) for rid, t in self.outputs.items()}
