"""Continuous-batching serve scheduler over the paged KV pool.

The repo's serving layer decoded one request (batch) at a time; this module
turns it into a slot-based continuous-batching system — the setting where
the paper's decision machinery actually earns its keep: a fixed array of
serving SLOTS decodes in lock-step inside ONE jitted ``lax.scan``, each
slot at its own position in its own request, so the DecisionModule sees a
genuinely interleaved multi-tenant write stream (per-slot destination
blocks in a SHARED physical pool) instead of a single flow.

Architecture (DESIGN.md §4):

* **SlotState** — per-slot token / position / done-flag / remaining-budget /
  sample-key / request-id, all fixed-shape int/bool arrays living in the
  scan carry. Retirement is IN-scan: a slot whose token hits EOS or whose
  budget is spent flips ``done`` and from the next step neither writes KV
  (its physical destination resolves to the drop sentinel) nor updates the
  page-frequency monitor.
* **Admission** — BETWEEN scan segments, on the host: the head of the FIFO
  ``RequestQueue`` is admitted into the lowest free slot once the
  :class:`~repro.kvcache.paged.BlockPool` can cover its page budget
  (head-of-line blocking preserves FIFO order), its prompt is prefilled
  (dense, contiguous — the offload path, as in the paper) and scattered
  into its freshly allocated blocks, and the slot arrays are updated
  in place. Retired slots return their blocks to the pool first.
* **KV writes** — every decode-time write resolves through the page table
  to a physical pool row; direct writes scatter straight in, staged writes
  ride the per-slot ring overlay and drain in bulk through
  ``core.ring.scatter_rows``. The monitor's region universe is the
  physical BLOCK id.

Two cache layouts:

* ``paged``  — dense non-SWA DecoderLM family: the paged pool + ring
  overlay (all three write modes). Bit-compatible with dense decode.
* ``lanes``  — every other family (SSM / hybrid / MoE / enc-dec / VLM /
  SWA): the model's own cache pytree with batch = n_slots; admission
  overwrites a retired slot's lane wholesale (every cache leaf carries
  batch on axis 1 — the repo-wide convention). Direct mode only, same
  scheduler machinery.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core.types import make_write_batch
from ..data.pipeline import RequestQueue
from ..kvcache import paged as PG
from ..models.transformer import DecoderLM, direct_kv_write
from .engine import WRITE_MODES, make_decision


def paged_capable(model) -> bool:
    """Can this model serve from the paged pool? Linear-addressed dense
    ``DecoderLM`` only: SWA's ring addressing IS its window bound, and the
    VLM grouped scan lacks the mask plumbing (DESIGN.md §Arch-applicability)."""
    return (isinstance(model, DecoderLM)
            and not model.is_vlm
            and not model.cfg.sliding_window)


class SlotState(NamedTuple):
    """Fixed slot array — the whole scheduler state inside the scan carry.

    token:     int32[S] last emitted token (next step's input)
    pos:       int32[S] logical position the next decode step writes
    done:      bool[S]  retired (or never admitted) — inactive slots
    remaining: int32[S] tokens the slot may still emit
    key:       uint32[S, 2] per-slot PRNG key data (sampled decode)
    req_id:    int32[S] owning request id (-1 = empty)
    """

    token: jnp.ndarray
    pos: jnp.ndarray
    done: jnp.ndarray
    remaining: jnp.ndarray
    key: jnp.ndarray
    req_id: jnp.ndarray


def make_slots(n_slots: int) -> SlotState:
    return SlotState(
        token=jnp.zeros((n_slots,), jnp.int32),
        pos=jnp.zeros((n_slots,), jnp.int32),
        done=jnp.ones((n_slots,), jnp.bool_),
        remaining=jnp.zeros((n_slots,), jnp.int32),
        key=jnp.zeros((n_slots, 2), jnp.uint32),
        req_id=jnp.full((n_slots,), -1, jnp.int32),
    )


@dataclasses.dataclass
class BatchConfig:
    """Continuous-batching engine configuration.

    ``max_seq`` bounds prompt_len + max_new per request; ``n_blocks = 0``
    sizes the pool for zero contention (n_slots * pages-per-slot).
    """

    max_seq: int
    n_slots: int = 8
    segment_len: int = 16
    write_mode: str = "direct"
    page_size: int = 8
    n_blocks: int = 0
    ring_size: int = 8
    hot_threshold: int = 4
    greedy: bool = True
    eos_id: Optional[int] = None
    drain_kernel: bool = False
    kv_layout: str = "auto"      # auto | paged | lanes
    sample_seed: int = 0


class BatchedServeEngine:
    """Slot-based continuous-batching serving engine.

    >>> eng = BatchedServeEngine(model, params, BatchConfig(max_seq=128))
    >>> outputs = eng.serve(queue)          # {req_id: np.ndarray tokens}
    """

    def __init__(self, model, params, cfg: BatchConfig):
        assert cfg.write_mode in WRITE_MODES, cfg.write_mode
        self.model = model
        self.params = params
        self.cfg = cfg

        layout = cfg.kv_layout
        if layout == "auto":
            layout = "paged" if paged_capable(model) else "lanes"
        if layout == "paged" and not paged_capable(model):
            raise ValueError(
                f"paged KV serves the linear-addressed dense family; "
                f"{model.cfg.name} needs kv_layout='lanes'"
            )
        if layout == "lanes" and cfg.write_mode != "direct":
            raise ValueError(
                "staged/adaptive write modes need the paged layout "
                "(ring overlay is wired for dense non-SWA caches)"
            )
        self.layout = layout

        ps = cfg.page_size
        self.max_pages = -(-cfg.max_seq // ps)
        self.n_blocks = cfg.n_blocks or cfg.n_slots * self.max_pages
        # region universe: physical pool blocks (paged) or per-slot pages
        # (lanes) — either way the monitor sees the interleaved stream
        n_regions = (self.n_blocks if layout == "paged"
                     else cfg.n_slots * self.max_pages)
        self.decision = make_decision(cfg.write_mode, n_regions,
                                      cfg.hot_threshold)
        self.mon_state = self.decision.init_state()

        if layout == "paged":
            shape = jax.eval_shape(lambda: model.init_cache(1, cfg.max_seq))
            l, _, _, h, dh = shape["k"].shape
            self.pool = PG.BlockPool(self.n_blocks)
            self.cache = PG.make_paged_kv(
                l, self.n_blocks, ps, cfg.n_slots, self.max_pages, h, dh,
                dtype=shape["k"].dtype,
                ring_size=cfg.ring_size if cfg.write_mode != "direct" else 0,
            )
        else:
            self.pool = None
            self.cache = model.init_cache(cfg.n_slots, cfg.max_seq)
        self.slots = make_slots(cfg.n_slots)

        # host-side shadows (device round-trips happen once per segment)
        self._occupied = [False] * cfg.n_slots
        self._slot_req: List[int] = [-1] * cfg.n_slots
        self._base_key = jax.random.key(cfg.sample_seed)
        self.outputs: Dict[int, List[int]] = {}
        self.stats = {
            "direct_writes": 0, "staged_writes": 0, "drains": 0,
            "segments": 0, "admitted": 0, "retired": 0,
        }
        self._segment_fn: Optional[Callable] = None
        self._prefill_fns: Dict[Any, Callable] = {}

    def reset(self) -> None:
        """Fresh serving state (cache, slots, pool, monitor, outputs) with
        the compiled segment function retained — benchmark/test runs can
        re-serve without paying compilation again."""
        cfg = self.cfg
        if self.layout == "paged":
            self.pool = PG.BlockPool(self.n_blocks)
            l, _, ps, h, dh = self.cache["pages_k"].shape
            self.cache = PG.make_paged_kv(
                l, self.n_blocks, ps, cfg.n_slots, self.max_pages, h, dh,
                dtype=self.cache["pages_k"].dtype,
                ring_size=cfg.ring_size if cfg.write_mode != "direct" else 0,
            )
        else:
            self.cache = self.model.init_cache(cfg.n_slots, cfg.max_seq)
        self.slots = make_slots(cfg.n_slots)
        self.mon_state = self.decision.init_state()
        self._occupied = [False] * cfg.n_slots
        self._slot_req = [-1] * cfg.n_slots
        self.outputs = {}
        self.stats = {k: 0 for k in self.stats}

    # ------------------------------------------------------------------
    # segment: the jitted inner loop
    # ------------------------------------------------------------------
    def _build_segment(self) -> Callable:
        model, cfg = self.model, self.cfg
        paged = self.layout == "paged"
        ring = paged and cfg.write_mode != "direct"
        ps, nb, mp = cfg.page_size, self.n_blocks, self.max_pages
        eos, greedy = cfg.eos_id, cfg.greedy
        decision = self.decision

        def step(params, carry, _):
            cache, st, mon, stats = carry
            active = ~st.done
            if paged:
                dest = PG.logical_to_physical(
                    cache, jnp.where(active, st.pos, -1))
                region = jnp.minimum(dest // ps, nb - 1)
            else:
                region = (jnp.arange(cfg.n_slots) * mp
                          + jnp.clip(st.pos // ps, 0, mp - 1))
            unload, mon, _ = decision(
                mon, make_write_batch(region), active=active)
            n_u = jnp.sum(unload.astype(jnp.int32))
            drained = jnp.zeros((), jnp.bool_)
            if ring:
                cache, drained = PG.maybe_drain(
                    cache, use_kernel=cfg.drain_kernel,
                    incoming_pos=jnp.where(active, st.pos, -1))
                logits, cache = model.decode_step_paged(
                    params, cache, st.token, st.pos, active,
                    unload_mask=unload)
            elif paged:
                logits, cache = model.decode_step_paged(
                    params, cache, st.token, st.pos, active)
            else:
                # retired slots never write: redirect their scatter rows
                # to the out-of-range drop sentinel (SSM recurrent state
                # has no KV scatter — its lane updates are slot-private
                # and overwritten wholesale at admission)
                def masked_writer(kc, vc, k_new, v_new, rows):
                    return direct_kv_write(
                        kc, vc, k_new, v_new,
                        jnp.where(active, rows, kc.shape[1]))

                logits, cache = model.decode_step(
                    params, cache, st.token, st.pos, kv_writer=masked_writer)
            if greedy:
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                key = st.key
            else:
                pairs = jax.vmap(jax.random.split)(
                    jax.random.wrap_key_data(st.key))
                nxt = jax.vmap(jax.random.categorical)(
                    pairs[:, 0], logits).astype(jnp.int32)
                key = jax.random.key_data(pairs[:, 1])
            nxt = jnp.where(active, nxt, st.token)
            remaining = st.remaining - active.astype(jnp.int32)
            ended = remaining <= 0
            if eos is not None:
                ended = ended | (nxt == eos)
            st = SlotState(
                token=nxt,
                pos=st.pos + active.astype(jnp.int32),
                done=st.done | (active & ended),
                remaining=remaining,
                key=key,
                req_id=st.req_id,
            )
            stats = stats + jnp.stack([
                jnp.sum(active.astype(jnp.int32)) - n_u,
                n_u,
                drained.astype(jnp.int32),
            ])
            emit = jnp.where(active, nxt, -1)
            return (cache, st, mon, stats), (emit, active)

        def run(params, cache, st, mon):
            stats0 = jnp.zeros((3,), jnp.int32)
            (cache, st, mon, stats), (emits, acts) = lax.scan(
                lambda c, x: step(params, c, x),
                (cache, st, mon, stats0),
                None,
                length=cfg.segment_len,
            )
            if ring:
                # segment boundary: the host may retire slots and free
                # their blocks next — the ring must not hold entries that
                # would later drain into reallocated blocks
                cache = PG.drain_ring(cache, use_kernel=cfg.drain_kernel)
            return cache, st, mon, stats, emits, acts

        return jax.jit(run)

    # ------------------------------------------------------------------
    # admission / retirement (host, between segments)
    # ------------------------------------------------------------------
    def _pages_needed(self, plen: int, max_new: int) -> int:
        # decode writes rows plen .. plen+max_new-2 (the final emitted
        # token is never consumed, so its KV is never written)
        return max(1, -(-(plen + max_new - 1) // self.cfg.page_size))

    def _prefill(self, prompts: jnp.ndarray, max_seq: int, media):
        """Jitted batched prefill, cached per (max_seq, media?) — jit
        re-specializes per (group size, prompt_len) shape on its own.
        Admission batches every same-length prompt into ONE prefill call;
        per-row results are bit-identical to solo prefills, so grouping is
        invisible to the decode stream."""
        key = (max_seq, media is not None)
        fn = self._prefill_fns.get(key)
        if fn is None:
            if media is None:
                fn = jax.jit(
                    lambda p, t: self.model.prefill(p, t, max_seq))
            else:
                fn = jax.jit(
                    lambda p, t, m: self.model.prefill(p, t, max_seq, media=m))
            self._prefill_fns[key] = fn
        args = (self.params, prompts) if media is None else (
            self.params, prompts, media)
        return fn(*args)

    def _admit_group(self, slots: List[int], reqs: List[Any],
                     blocks: List[Optional[np.ndarray]]) -> None:
        """Admit a group of same-prompt-length requests with ONE batched
        prefill + ONE insert + ONE slot-state update."""
        cfg = self.cfg
        g, plen = len(reqs), reqs[0].prompt_len
        ps = cfg.page_size
        prompts = jnp.asarray(np.stack([r.prompt for r in reqs]), jnp.int32)
        media = None
        if reqs[0].media is not None:
            media = jnp.asarray(np.stack([r.media for r in reqs]))
        slot_arr = jnp.asarray(slots, jnp.int32)

        if self.layout == "paged":
            logits, pc = self._prefill(prompts, plen, media)
            cache = self.cache
            l, nbp = cache["pages_k"].shape[0], PG.pool_rows(cache)
            rows = np.arange(plen)
            phys = np.concatenate(
                [b[rows // ps] * ps + rows % ps for b in blocks])
            phys = jnp.asarray(phys, jnp.int32)
            for pk, src in (("pages_k", "k"), ("pages_v", "v")):
                flat = cache[pk].reshape((l, nbp) + cache[pk].shape[3:])
                vals = pc[src][:, :, :plen]  # [L, g, plen, H, Dh]
                flat = flat.at[:, phys].set(
                    vals.reshape((l, g * plen) + vals.shape[3:]))
                cache[pk] = flat.reshape(cache[pk].shape)
            padded = np.full((g, self.max_pages), -1, np.int32)
            for i, b in enumerate(blocks):
                padded[i, : len(b)] = b
            cache["page_table"] = cache["page_table"].at[slot_arr].set(
                jnp.asarray(padded))
            regions = np.concatenate([b[rows // ps] for b in blocks])
        else:
            logits, pc = self._prefill(prompts, cfg.max_seq, media)
            self.cache = jax.tree.map(
                lambda big, small: big.at[:, slot_arr].set(small),
                self.cache, pc,
            )
            regions = np.concatenate([
                s * self.max_pages + np.arange(plen) // ps for s in slots])
        # prefill writes are dense/contiguous -> offload path; they still
        # heat the page counters (the paper's frequency monitor sees every
        # write that lands in a region)
        self.mon_state = self.decision.monitor.update(
            self.mon_state, jnp.asarray(regions, jnp.int32))

        t0s = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        keys = jax.random.key_data(jax.vmap(
            lambda i: jax.random.fold_in(self._base_key, i)
        )(jnp.asarray([r.req_id for r in reqs], jnp.int32)))
        rem = np.asarray([r.max_new - 1 for r in reqs], np.int32)
        done0 = rem <= 0
        if cfg.eos_id is not None:
            done0 = done0 | (t0s == cfg.eos_id)
        st = self.slots
        self.slots = SlotState(
            token=st.token.at[slot_arr].set(jnp.asarray(t0s)),
            pos=st.pos.at[slot_arr].set(plen),
            done=st.done.at[slot_arr].set(jnp.asarray(done0)),
            remaining=st.remaining.at[slot_arr].set(jnp.asarray(rem)),
            key=st.key.at[slot_arr].set(keys),
            req_id=st.req_id.at[slot_arr].set(
                jnp.asarray([r.req_id for r in reqs], jnp.int32)),
        )
        for slot, req, t0 in zip(slots, reqs, t0s):
            self._occupied[slot] = True
            self._slot_req[slot] = req.req_id
            self.outputs[req.req_id] = [int(t0)]
        self.stats["admitted"] += g

    def _retire(self, slots: List[int]) -> None:
        for slot in slots:
            if self.pool is not None:
                self.pool.free_slot(slot)
            self._occupied[slot] = False
            self._slot_req[slot] = -1
        if self.pool is not None and slots:
            self.cache["page_table"] = self.cache["page_table"].at[
                jnp.asarray(slots, jnp.int32)].set(-1)
        self.stats["retired"] += len(slots)

    def admit(self, queue: RequestQueue) -> int:
        """Admit from the queue head into free slots (FIFO: head-of-line
        blocks when the pool can't cover it). Same-prompt-length requests
        admitted together share one batched prefill. Returns #admitted."""
        picks: List[tuple] = []  # (slot, req, blocks)
        for slot in range(self.cfg.n_slots):
            if not queue:
                break
            if self._occupied[slot]:
                continue
            req = queue.peek()
            if req.prompt_len + req.max_new > self.cfg.max_seq:
                raise ValueError(
                    f"request {req.req_id}: prompt_len+max_new "
                    f"{req.prompt_len + req.max_new} > max_seq {self.cfg.max_seq}"
                )
            blocks = None
            if self.pool is not None:
                needed = self._pages_needed(req.prompt_len, req.max_new)
                if needed > self.pool.n_blocks:
                    raise ValueError(
                        f"request {req.req_id} needs {needed} blocks; "
                        f"pool holds {self.pool.n_blocks}")
                blocks = self.pool.alloc(slot, needed)
                if blocks is None:
                    break  # FIFO: wait for retirements, don't skip ahead
            picks.append((slot, queue.pop(), blocks))
        # group same-length prompts into one prefill dispatch each
        groups: Dict[int, List[tuple]] = {}
        for p in picks:
            groups.setdefault(p[1].prompt_len, []).append(p)
        for members in groups.values():
            self._admit_group([m[0] for m in members],
                              [m[1] for m in members],
                              [m[2] for m in members])
        return len(picks)

    # ------------------------------------------------------------------
    # the serve loop
    # ------------------------------------------------------------------
    def run_segment(self) -> np.ndarray:
        """One jitted scan segment + ONE host readback. Returns the bool
        [segment_len, n_slots] activity matrix (which steps emitted)."""
        if self._segment_fn is None:
            self._segment_fn = self._build_segment()
        self.cache, self.slots, self.mon_state, stats, emits, acts = (
            self._segment_fn(self.params, self.cache, self.slots,
                             self.mon_state))
        emits, acts = np.asarray(emits), np.asarray(acts)
        d, s, dr = (int(x) for x in stats)
        self.stats["direct_writes"] += d
        self.stats["staged_writes"] += s
        self.stats["drains"] += dr
        self.stats["segments"] += 1
        for slot in range(self.cfg.n_slots):
            if self._occupied[slot]:
                toks = emits[acts[:, slot], slot]
                self.outputs[self._slot_req[slot]].extend(
                    int(t) for t in toks)
        return acts

    def retire_done(self) -> int:
        """Free every occupied-but-done slot (host, between segments)."""
        done = np.asarray(self.slots.done)
        retiring = [s for s in range(self.cfg.n_slots)
                    if self._occupied[s] and bool(done[s])]
        self._retire(retiring)
        return len(retiring)

    def serve(self, queue: RequestQueue,
              max_segments: int = 100_000) -> Dict[int, np.ndarray]:
        """Drain the queue to completion: admit / scan a segment / collect /
        retire, until no request is live. Returns {req_id: tokens}."""
        for _ in range(max_segments):
            self.retire_done()
            self.admit(queue)
            if not any(self._occupied):
                # admit() marks every admitted slot occupied, so an empty
                # engine here means nothing was admittable
                if len(queue) == 0:
                    break
                raise RuntimeError(
                    "queue head unadmittable with an empty engine "
                    "(request larger than pool capacity?)")
            # all-done slot arrays would make the segment a no-op: only
            # scan when at least one slot is live
            if bool(np.all(np.asarray(self.slots.done))):
                continue
            self.run_segment()
        else:
            raise RuntimeError(f"serve() exceeded {max_segments} segments")
        return {rid: np.asarray(t, np.int32) for rid, t in self.outputs.items()}
