from .engine import WRITE_MODES, ServeConfig, ServeEngine

__all__ = ["WRITE_MODES", "ServeConfig", "ServeEngine"]
