"""Serving layer.

Public surface (pinned by ``tests/test_api_snapshot.py``):

* :class:`Engine` / :class:`EngineConfig` — THE front door: per-request
  ``SamplingParams``, ``generate``/``stream`` returning
  :class:`Completion` objects, write path + routing policy chosen by
  registry name.
* ``ServeEngine`` / ``BatchedServeEngine`` — deprecated constructor
  shims (one release): fully functional, but new code should go through
  ``Engine.from_config``.
"""
from ..models.sampling import SamplingParams
from .api import (
    Completion,
    Engine,
    EngineConfig,
    StreamEvent,
    build_model_and_params,
)
from .engine import WRITE_MODES, ServeConfig, ServeEngine, make_decision
from .scheduler import BatchConfig, BatchedServeEngine, SlotState, make_slots

__all__ = [
    "Engine",
    "EngineConfig",
    "Completion",
    "SamplingParams",
    "StreamEvent",
    "build_model_and_params",
    "WRITE_MODES",
    "ServeConfig",
    "ServeEngine",
    "make_decision",
    "BatchConfig",
    "BatchedServeEngine",
    "SlotState",
    "make_slots",
]
