from .engine import WRITE_MODES, ServeConfig, ServeEngine, make_decision
from .scheduler import BatchConfig, BatchedServeEngine, SlotState, make_slots

__all__ = [
    "WRITE_MODES",
    "ServeConfig",
    "ServeEngine",
    "make_decision",
    "BatchConfig",
    "BatchedServeEngine",
    "SlotState",
    "make_slots",
]
