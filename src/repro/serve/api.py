"""The serving front door: one ``Engine`` facade over every scheduler.

``Engine.from_config(EngineConfig(...))`` subsumes the legacy
``ServeEngine`` / ``BatchedServeEngine`` split: cache layout, scheduling
mode (blocking vs chunked prefill), and the write path/policy pair are
CONFIG, not class choice — the offload/unload machinery stays pluggable
behind one stable request/response surface (the paper's two-path
contract, served through the ``repro.core.paths`` registry).

Requests are ``(prompt, SamplingParams)`` pairs; results are
:class:`Completion` objects carrying per-request telemetry — TTFT,
finish reason, and the write-path split (direct / staged / prefill
counts) the request's KV writes took. ``Engine.stream`` yields tokens as
scan segments retire them; ``Engine.generate`` drains to completion.

>>> eng = Engine.from_config(EngineConfig(arch="stablelm-1.6b", max_seq=64))
>>> [c] = eng.generate([[1, 2, 3]], SamplingParams(max_tokens=8))
>>> c.tokens, c.ttft_s, c.path_counts
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterator, List, Optional, Sequence, Union

import jax
import numpy as np

from ..models.sampling import SamplingParams
from .scheduler import BatchConfig, BatchedServeEngine

__all__ = [
    "Completion",
    "Engine",
    "EngineConfig",
    "StreamEvent",
    "build_model_and_params",
]


def build_model_and_params(arch: str, max_seq: int, *, seed: int = 0,
                           reduced: bool = True):
    """(cfg, model, params) for a registered architecture — the one
    model-construction block the examples/benchmarks/CLIs share."""
    from ..configs import get_config
    from ..models import build_model

    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(seed), max_seq)
    return cfg, model, params


@dataclasses.dataclass
class EngineConfig:
    """Everything ``Engine.from_config`` needs — model choice, scheduler
    shape, write path/policy, and sampling defaults — in one place.

    ``path``/``policy`` name entries in the ``repro.core.paths`` /
    ``repro.core.policy`` registries (capability-negotiated against
    ``kv_layout``/``chunked`` at construction). ``default_params``
    applies to requests submitted without ``SamplingParams``, and its
    temperature also backfills requests whose own temperature is left
    ``None`` (see ``repro.models.sampling.resolve``).

    ``attention`` picks the paged decode read implementation —
    ``"fused"`` (the ``flash_decode_paged`` kernel), ``"reference"``
    (the jnp oracle), or ``"auto"`` (fused wherever the kernel compiles
    natively; negotiated through ``core.paths.resolve_attention``).
    ``drain_kernel=None`` auto-selects the ``staged_scatter`` drain
    kernel the same way.
    """

    max_seq: int
    arch: Optional[str] = None        # None when (model, params) are passed
    reduced: bool = True
    init_seed: int = 0
    # scheduler shape
    n_slots: int = 8
    segment_len: int = 16
    chunked: bool = False
    chunk_size: int = 8
    kv_layout: str = "auto"           # auto | paged | lanes
    # write path + decision plane (registry names)
    path: str = "direct"
    policy: Optional[str] = None
    page_size: int = 8
    n_blocks: int = 0
    ring_size: int = 8
    hot_threshold: int = 4
    drain_kernel: Optional[bool] = None
    attention: str = "auto"           # auto | fused | reference
    # sampling
    default_params: Optional[SamplingParams] = None
    eos_id: Optional[int] = None
    sample_seed: int = 0

    def batch_config(self) -> BatchConfig:
        d = self.default_params
        return BatchConfig(
            max_seq=self.max_seq,
            n_slots=self.n_slots,
            segment_len=self.segment_len,
            page_size=self.page_size,
            n_blocks=self.n_blocks,
            ring_size=self.ring_size,
            hot_threshold=self.hot_threshold,
            greedy=(d is None or d.temperature is None
                    or d.temperature == 0.0),
            eos_id=self.eos_id,
            drain_kernel=self.drain_kernel,
            attention=self.attention,
            kv_layout=self.kv_layout,
            sample_seed=self.sample_seed,
            chunked=self.chunked,
            chunk_size=self.chunk_size,
            path=self.path,
            policy=self.policy,
            default_params=d,
        )


@dataclasses.dataclass
class Completion:
    """One finished request, with its telemetry.

    tokens        the emitted stream (np.int32, includes the prefill
                  token)
    params        the request's RESOLVED SamplingParams
    ttft_s        seconds from serve start to the first emitted token
    finish_reason ``"stop"`` (stop-token hit) or ``"length"`` (budget)
    path_counts   how this request's KV writes were routed:
                  {"direct", "staged", "prefill"} (prefill = bulk rows
                  pinned to the offload path)
    """

    req_id: int
    tokens: np.ndarray
    params: SamplingParams
    ttft_s: float
    finish_reason: str
    path_counts: Dict[str, int]

    @property
    def n_tokens(self) -> int:
        return int(self.tokens.shape[0])


@dataclasses.dataclass
class StreamEvent:
    """One streaming update: the tokens a request gained in the latest
    scan segment, plus its :class:`Completion` once it finishes."""

    req_id: int
    tokens: np.ndarray                 # the NEW tokens this event
    done: bool
    completion: Optional[Completion] = None


class Engine:
    """The one serving front door (see module docstring).

    Construct via :meth:`from_config`; the underlying continuous-batching
    scheduler (slots, paged pool / lanes, write-path machinery) is an
    implementation detail reachable at ``engine.scheduler`` for tests and
    benchmarks that need the internals.
    """

    def __init__(self, model, params, cfg: EngineConfig):
        self.cfg = cfg
        self.model = model
        self.params = params
        self.scheduler = BatchedServeEngine(
            model, params, cfg.batch_config(), _warn=False)

    # ------------------------------------------------------------------
    @classmethod
    def from_config(cls, cfg: EngineConfig, model=None, params=None) -> "Engine":
        """Build the engine from config alone (``cfg.arch`` names a
        registered architecture) or around an existing (model, params)
        pair."""
        if model is None:
            if cfg.arch is None:
                raise ValueError(
                    "EngineConfig.arch is required when no model is passed")
            _, model, params = build_model_and_params(
                cfg.arch, cfg.max_seq, seed=cfg.init_seed,
                reduced=cfg.reduced)
        elif params is None:
            raise ValueError("passing model without params")
        return cls(model, params, cfg)

    # ------------------------------------------------------------------
    @property
    def layout(self) -> str:
        return self.scheduler.layout

    @property
    def stats(self) -> Dict[str, int]:
        return self.scheduler.stats

    @property
    def ttft(self) -> Dict[int, float]:
        return self.scheduler.ttft

    def reset(self) -> None:
        """Fresh serving state; compiled segment functions are retained."""
        self.scheduler.reset()

    # ------------------------------------------------------------------
    def _make_queue(self, prompts: Sequence, params, media):
        from ..data.pipeline import RequestQueue

        n = len(prompts)
        if params is None or isinstance(params, SamplingParams):
            plist = [params] * n
        else:
            plist = list(params)
            if len(plist) != n:
                raise ValueError(
                    f"{len(plist)} SamplingParams for {n} prompts")
        mlist = [None] * n if media is None else list(media)
        if len(mlist) != n:
            raise ValueError(f"{len(mlist)} media entries for {n} prompts")
        q = RequestQueue()
        for prompt, p, m in zip(prompts, plist, mlist):
            q.submit(prompt, media=m,
                     params=p or self.cfg.default_params or SamplingParams())
        return q

    def _completion(self, rid: int) -> Completion:
        eng = self.scheduler
        tokens = np.asarray(eng.outputs[rid], np.int32)
        params = eng.req_params[rid]
        stop = set(params.stop_token_ids)
        if self.cfg.eos_id is not None:
            stop.add(self.cfg.eos_id)
        reason = ("stop" if len(tokens) and int(tokens[-1]) in stop
                  else "length")
        d, s, p = (int(x) for x in eng.req_writes[rid])
        return Completion(
            req_id=rid,
            tokens=tokens,
            params=params,
            ttft_s=float(eng.ttft.get(rid, 0.0)),
            finish_reason=reason,
            path_counts={"direct": d, "staged": s, "prefill": p},
        )

    # ------------------------------------------------------------------
    def stream(self, prompts: Sequence, params: Union[
            SamplingParams, Sequence[Optional[SamplingParams]], None] = None,
            media: Optional[Sequence] = None,
            max_segments: int = 100_000) -> Iterator[StreamEvent]:
        """Serve ``prompts`` and yield :class:`StreamEvent`s as scan
        segments emit tokens (requests stream concurrently; each event
        carries one request's new tokens). The final event for a request
        has ``done=True`` and its :class:`Completion`.
        """
        queue = self._make_queue(prompts, params, media)
        yield from self.serve_stream(queue, max_segments=max_segments)

    def serve_stream(self, queue, max_segments: int = 100_000,
                     ) -> Iterator[StreamEvent]:
        """`stream` over an explicit ``RequestQueue`` (power API: mixed
        media, pre-built synthetic workloads)."""
        eng = self.scheduler
        if eng.outputs:
            eng.reset()
        if eng._t_serve0 is None:
            # TTFT baseline = serve start (matches scheduler.serve):
            # admission prefill and compile time count toward the first
            # wave's TTFT instead of reading as 0.0
            eng._t_serve0 = time.perf_counter()
        sent: Dict[int, int] = {}
        finished: set = set()

        def drain_events():
            # report in request order for determinism; done-ness comes
            # from the slot state (retirement happens next loop turn)
            done_now = {eng._slot_req[s]
                        for s in range(eng.cfg.n_slots)
                        if eng._occupied[s] and bool(done_flags[s])}
            for rid in sorted(eng.outputs):
                if rid in finished:
                    continue
                new = eng.outputs[rid][sent.get(rid, 0):]
                is_done = rid in done_now
                if new or is_done:
                    sent[rid] = len(eng.outputs[rid])
                    completion = None
                    if is_done:
                        finished.add(rid)
                        completion = self._completion(rid)
                    yield StreamEvent(
                        req_id=rid,
                        tokens=np.asarray(new, np.int32),
                        done=is_done,
                        completion=completion,
                    )

        for _ in range(max_segments):
            eng.retire_done()
            eng.admit(queue)
            if not any(eng._occupied):
                if len(queue) == 0:
                    return
                raise RuntimeError(
                    "queue head unadmittable with an empty engine "
                    "(request larger than pool capacity?)")
            live = ~np.asarray(eng.slots.done) & np.asarray(eng._occupied)
            if live.any():
                enabled = eng._topup_blocks()
                if not (live & enabled).any():
                    raise RuntimeError(
                        "every live slot stalled on block top-up: the pool "
                        "is too small for the admitted working set")
                eng.run_segment(enabled)
            done_flags = np.asarray(eng.slots.done)
            yield from drain_events()
        raise RuntimeError(f"stream() exceeded {max_segments} segments")

    # ------------------------------------------------------------------
    def generate(self, prompts: Sequence, params: Union[
            SamplingParams, Sequence[Optional[SamplingParams]], None] = None,
            media: Optional[Sequence] = None) -> List[Completion]:
        """Serve ``prompts`` to completion; returns one
        :class:`Completion` per prompt, in submission order."""
        done = {ev.req_id: ev.completion
                for ev in self.stream(prompts, params, media) if ev.done}
        return [done[rid] for rid in sorted(done)]

    def serve(self, queue, max_segments: int = 100_000,
              ) -> Dict[int, np.ndarray]:
        """Drain an explicit ``RequestQueue``; returns {req_id: tokens}
        (the legacy scheduler surface, kept for benchmarks/tests)."""
        return self.scheduler.serve(queue, max_segments=max_segments)

    def completions(self) -> Dict[int, Completion]:
        """Completions for every request served so far (post ``serve``)."""
        return {rid: self._completion(rid)
                for rid in self.scheduler.outputs}
