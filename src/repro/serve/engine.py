"""Serving engine: batched prefill + decode with uRDMA KV-write routing.

Write modes (per paper §3):
  direct    every KV write scatters straight into the cache (offload path)
  staged    every write appends to the staging ring; bulk drain when full
            (unload path)
  adaptive  the decision module routes per sequence: sequences whose
            destination pages are HOT (frequency counters over page ids)
            write direct; cold ones are staged. Counters update per step —
            the paper's frequency policy on KV pages.

The engine is model-agnostic (any family exposing prefill/decode_step);
staged/adaptive need ring-overlay support (dense DecoderLM family).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.monitor import ExactMonitor
from ..kvcache import add_ring, drain_ring, maybe_drain, strip_ring

WRITE_MODES = ("direct", "staged", "adaptive")


@dataclasses.dataclass
class ServeConfig:
    max_seq: int
    write_mode: str = "direct"
    ring_size: int = 8
    page_size: int = 64          # page granularity for hotness accounting
    hot_threshold: int = 4       # counts above -> page considered hot
    greedy: bool = True


class ServeEngine:
    def __init__(self, model, params, cfg: ServeConfig):
        assert cfg.write_mode in WRITE_MODES, cfg.write_mode
        self.model = model
        self.params = params
        self.cfg = cfg
        n_pages = max(1, cfg.max_seq // cfg.page_size)
        self.page_monitor = ExactMonitor(n_regions=n_pages)
        self.mon_state = self.page_monitor.init()
        self.stats = {"direct_writes": 0, "staged_writes": 0, "drains": 0}

    # ------------------------------------------------------------------
    def prefill(self, tokens: jnp.ndarray, media=None) -> Tuple[jnp.ndarray, Any]:
        kw = {"media": media} if media is not None else {}
        logits, cache = self.model.prefill(
            self.params, tokens, self.cfg.max_seq, **kw
        )
        if self.cfg.write_mode in ("staged", "adaptive"):
            cache = add_ring(cache, self.cfg.ring_size)
        # prefill writes are dense/contiguous -> they stay on the offload
        # path (the paper unloads only small scattered writes)
        pages = jnp.arange(tokens.shape[1]) // self.cfg.page_size
        self.mon_state = self.page_monitor.update(self.mon_state, pages)
        return logits, cache

    # ------------------------------------------------------------------
    def _unload_mask(self, slots: jnp.ndarray) -> Optional[jnp.ndarray]:
        mode = self.cfg.write_mode
        if mode == "direct":
            return None
        if mode == "staged":
            return jnp.ones_like(slots, jnp.bool_)
        # adaptive: cold destination pages -> unload
        pages = slots // self.cfg.page_size
        counts = self.page_monitor.query(self.mon_state, pages)
        return counts < self.cfg.hot_threshold

    def decode(
        self,
        cache: Any,
        first_tokens: jnp.ndarray,
        start_pos: jnp.ndarray,
        n_steps: int,
        sample_key: Optional[jax.Array] = None,
    ) -> Tuple[jnp.ndarray, Any]:
        """Greedy (or sampled) decode loop. Returns (tokens [B, n], cache)."""
        b = first_tokens.shape[0]
        tokens = first_tokens
        out = []
        for t in range(n_steps):
            pos = start_pos + t
            slots = jnp.minimum(pos, self.cfg.max_seq - 1)
            unload = self._unload_mask(slots)
            kw = {}
            if self.cfg.write_mode != "direct":
                kw["unload_mask"] = unload
            logits, cache = self.model.decode_step(
                self.params, cache, tokens, pos, **kw
            )
            # monitor update: destination pages written this step
            pages = slots // self.cfg.page_size
            self.mon_state = self.page_monitor.update(self.mon_state, pages)
            if unload is not None:
                n_u = int(jnp.sum(unload))
                self.stats["staged_writes"] += n_u
                self.stats["direct_writes"] += b - n_u
                before = int(cache["ring_fill"])
                cache = maybe_drain(cache)
                if int(cache["ring_fill"]) < before:
                    self.stats["drains"] += 1
            else:
                self.stats["direct_writes"] += b

            if self.cfg.greedy or sample_key is None:
                tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                sample_key, sub = jax.random.split(sample_key)
                tokens = jax.random.categorical(sub, logits).astype(jnp.int32)
            out.append(tokens)

        if self.cfg.write_mode != "direct":
            cache = drain_ring(cache, use_kernel=False)
        return jnp.stack(out, axis=1), cache

    # ------------------------------------------------------------------
    def generate(
        self, prompt: jnp.ndarray, n_steps: int, media=None,
        sample_key: Optional[jax.Array] = None,
    ) -> jnp.ndarray:
        """Convenience: prefill + decode. prompt [B, S] -> tokens [B, n]."""
        logits, cache = self.prefill(prompt, media)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        start = jnp.full((prompt.shape[0],), prompt.shape[1], jnp.int32)
        toks, cache = self.decode(cache, first, start, n_steps - 1, sample_key)
        if self.cfg.write_mode != "direct":
            cache = strip_ring(cache)
        return jnp.concatenate([first[:, None], toks], axis=1)
