"""Serving engine: batched prefill + fully device-resident decode with
uRDMA KV-write routing.

Write modes (per paper §3):
  direct    every KV write scatters straight into the cache (offload path)
  staged    every write appends to the staging ring; bulk drain when full
            or when a destination conflicts with a pending entry
            (unload path)
  adaptive  the decision module routes per sequence: sequences whose
            destination pages are HOT (frequency counters over page ids)
            write direct; cold ones are staged. Counters update per step —
            the paper's frequency policy on KV pages.

Routing goes through ``core.decision.DecisionModule`` — the same
monitor/policy composition the ``RemoteWriteEngine`` uses — so the serving
layer has no private path-selection logic (paper Idea 3: one decision
plane for every write surface).

The decode loop is ONE ``lax.scan`` under ``jax.jit``: cache, staging
ring, monitor state, PRNG key, and int32 telemetry counters all live in a
fixed-shape carry; drains are ``lax.cond`` branches (full OR
conflict-forced); per-step routing statistics accumulate on device and are
read back ONCE per call. The paper's requirement that the decision run
"faster than the expected savings" is unmeetable if every step pays a
host round-trip — the seed's Python loop did exactly that
(``int(jnp.sum(unload))`` per step). That loop survives as
``decode_reference`` (parity oracle + benchmark baseline).

The engine is model-agnostic (any family exposing prefill/decode_step);
staged/adaptive need ring-overlay support (dense DecoderLM family).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..core.decision import DecisionModule
from ..core.paths import build_decision
from ..core.types import make_write_batch
from ..kvcache import add_ring, drain_ring, maybe_drain, strip_ring

# Legacy write-mode strings == the built-in WritePath registry names
# (repro.core.paths); kept for the deprecation window.
WRITE_MODES = ("direct", "staged", "adaptive")


def make_decision(write_mode: str, n_regions: int,
                  hot_threshold: int) -> DecisionModule:
    """Deprecated shim: the decision plane is built from the path/policy
    registries now (``repro.core.paths.build_decision``); each legacy
    write mode resolves to the same-named built-in path and its default
    policy (direct -> always-offload, staged -> always-unload,
    adaptive -> frequency)."""
    assert write_mode in WRITE_MODES, write_mode
    _, module = build_decision(write_mode, n_regions=n_regions,
                               hot_threshold=hot_threshold)
    return module


@dataclasses.dataclass
class ServeConfig:
    max_seq: int
    write_mode: str = "direct"
    ring_size: int = 8
    page_size: int = 64          # page granularity for hotness accounting
    hot_threshold: int = 4       # counts above -> page considered hot
    greedy: bool = True
    drain_kernel: bool = False   # drain via the Pallas staged_scatter kernel


class ServeEngine:
    def __init__(self, model, params, cfg: ServeConfig, _warn: bool = True):
        if _warn:
            warnings.warn(
                "constructing ServeEngine directly is deprecated; use "
                "repro.serve.Engine.from_config(...) — the shim stays for "
                "one release",
                DeprecationWarning, stacklevel=2)
        assert cfg.write_mode in WRITE_MODES, cfg.write_mode
        self.model = model
        self.params = params
        self.cfg = cfg
        n_pages = max(1, cfg.max_seq // cfg.page_size)
        self.decision = make_decision(cfg.write_mode, n_pages, cfg.hot_threshold)
        self.page_monitor = self.decision.monitor
        self.mon_state = self.decision.init_state()
        self.stats = {"direct_writes": 0, "staged_writes": 0, "drains": 0}
        self._decode_fns: Dict[Tuple, Callable] = {}

    # ------------------------------------------------------------------
    def prefill(self, tokens: jnp.ndarray, media=None) -> Tuple[jnp.ndarray, Any]:
        kw = {"media": media} if media is not None else {}
        logits, cache = self.model.prefill(
            self.params, tokens, self.cfg.max_seq, **kw
        )
        if self.cfg.write_mode in ("staged", "adaptive"):
            cache = add_ring(cache, self.cfg.ring_size)
        # prefill writes are dense/contiguous -> they stay on the offload
        # path (the paper unloads only small scattered writes)
        pages = jnp.arange(tokens.shape[1]) // self.cfg.page_size
        self.mon_state = self.page_monitor.update(self.mon_state, pages)
        return logits, cache

    # ------------------------------------------------------------------
    def _step_slots(self, pos: jnp.ndarray) -> jnp.ndarray:
        return jnp.minimum(pos, self.cfg.max_seq - 1)

    def _decode_fn(self, n_steps: int, greedy: bool) -> Callable:
        """Jitted whole-loop decode, cached per (n_steps, sampling mode)."""
        key = (n_steps, greedy)
        fn = self._decode_fns.get(key)
        if fn is not None:
            return fn

        cfg = self.cfg
        ring = cfg.write_mode != "direct"

        def run(params, cache, first_tokens, start_pos, mon_state, sample_key):
            b = first_tokens.shape[0]

            def step(carry, t):
                cache, tokens, mon, skey, stats = carry
                pos = start_pos + t
                slots = self._step_slots(pos)
                # route this step's KV writes: monitor update + policy
                # compare, fully on device (core.decision hot path)
                batch = make_write_batch(slots // cfg.page_size)
                unload, mon, _ = self.decision(mon, batch)
                n_u = jnp.sum(unload.astype(jnp.int32))
                if ring:
                    # drain BEFORE the append when the ring is out of room
                    # or this step's destinations collide with pending
                    # entries (keeps drain batches unique-destination —
                    # the staged_scatter precondition)
                    cache, drained = maybe_drain(
                        cache, use_kernel=cfg.drain_kernel,
                        incoming_slots=slots,
                    )
                    logits, cache = self.model.decode_step(
                        params, cache, tokens, pos, unload_mask=unload
                    )
                else:
                    drained = jnp.zeros((), jnp.bool_)
                    logits, cache = self.model.decode_step(
                        params, cache, tokens, pos
                    )
                if greedy:
                    tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                else:
                    skey, sub = jax.random.split(skey)
                    tokens = jax.random.categorical(sub, logits).astype(jnp.int32)
                stats = stats + jnp.stack(
                    [b - n_u, n_u, drained.astype(jnp.int32)]
                )
                return (cache, tokens, mon, skey, stats), tokens

            stats0 = jnp.zeros((3,), jnp.int32)
            (cache, _, mon, _, stats), toks = lax.scan(
                step,
                (cache, first_tokens, mon_state, sample_key, stats0),
                jnp.arange(n_steps, dtype=jnp.int32),
            )
            if ring:
                cache = drain_ring(cache, use_kernel=cfg.drain_kernel)
            return jnp.moveaxis(toks, 0, 1), cache, mon, stats

        fn = jax.jit(run)
        self._decode_fns[key] = fn
        return fn

    def decode(
        self,
        cache: Any,
        first_tokens: jnp.ndarray,
        start_pos: jnp.ndarray,
        n_steps: int,
        sample_key: Optional[jax.Array] = None,
    ) -> Tuple[jnp.ndarray, Any]:
        """Greedy (or sampled) decode loop. Returns (tokens [B, n], cache).

        The full loop — model steps, ring drains, routing decisions,
        telemetry — runs as one compiled ``lax.scan``; the only host
        transfer is the final (tokens, stats) readback.
        """
        greedy = self.cfg.greedy or sample_key is None
        if sample_key is None:
            sample_key = jax.random.key(0)  # unused on the greedy path
        fn = self._decode_fn(int(n_steps), greedy)
        toks, cache, self.mon_state, stats = fn(
            self.params, cache, first_tokens, start_pos, self.mon_state,
            sample_key,
        )
        d, s, n_drains = (int(x) for x in stats)  # ONE readback per call
        self.stats["direct_writes"] += d
        self.stats["staged_writes"] += s
        self.stats["drains"] += n_drains
        return toks, cache

    # ------------------------------------------------------------------
    def decode_reference(
        self,
        cache: Any,
        first_tokens: jnp.ndarray,
        start_pos: jnp.ndarray,
        n_steps: int,
        sample_key: Optional[jax.Array] = None,
    ) -> Tuple[jnp.ndarray, Any]:
        """The seed's per-step Python loop: one ``decode_step`` dispatch and
        a host telemetry round-trip per token. Kept as the parity oracle
        for :meth:`decode` and the benchmark baseline
        (``benchmarks/serve_modes.py`` reports both)."""
        b = first_tokens.shape[0]
        tokens = first_tokens
        out = []
        ring = self.cfg.write_mode != "direct"
        for t in range(n_steps):
            pos = start_pos + t
            slots = self._step_slots(pos)
            batch = make_write_batch(slots // self.cfg.page_size)
            unload, self.mon_state, _ = self.decision(self.mon_state, batch)
            if ring:
                cache, drained = maybe_drain(
                    cache, use_kernel=self.cfg.drain_kernel,
                    incoming_slots=slots,
                )
                self.stats["drains"] += int(drained)        # host sync
                n_u = int(jnp.sum(unload))                  # host sync
                self.stats["staged_writes"] += n_u
                self.stats["direct_writes"] += b - n_u
                logits, cache = self.model.decode_step(
                    self.params, cache, tokens, pos, unload_mask=unload
                )
            else:
                self.stats["direct_writes"] += b
                logits, cache = self.model.decode_step(
                    self.params, cache, tokens, pos
                )
            if self.cfg.greedy or sample_key is None:
                tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                sample_key, sub = jax.random.split(sample_key)
                tokens = jax.random.categorical(sub, logits).astype(jnp.int32)
            out.append(tokens)

        if ring:
            cache = drain_ring(cache, use_kernel=self.cfg.drain_kernel)
        if out:
            return jnp.stack(out, axis=1), cache
        return jnp.zeros((b, 0), jnp.int32), cache

    # ------------------------------------------------------------------
    def generate(
        self, prompt: jnp.ndarray, n_steps: int, media=None,
        sample_key: Optional[jax.Array] = None,
        reference: bool = False,
    ) -> jnp.ndarray:
        """Convenience: prefill + decode. prompt [B, S] -> tokens [B, n]."""
        logits, cache = self.prefill(prompt, media)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        start = jnp.full((prompt.shape[0],), prompt.shape[1], jnp.int32)
        step = self.decode_reference if reference else self.decode
        toks, cache = step(cache, first, start, n_steps - 1, sample_key)
        if self.cfg.write_mode != "direct":
            cache = strip_ring(cache)
        return jnp.concatenate([first[:, None], toks], axis=1)
