"""AdamW (decoupled weight decay) + global-norm clipping + LR schedules.

Self-contained (no optax in this container): optimizer states are plain
pytrees mirroring the parameter tree, so they shard with the same
NamedShardings as the parameters (FSDP-friendly) and checkpoint through the
same manifest code path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray  # int32 scalar
    mu: Any            # first moment (pytree like params)
    nu: Any            # second moment


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params: Any) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p), params)
        return AdamWState(jnp.zeros((), jnp.int32), zeros, zeros)

    def _lr(self, step: jnp.ndarray) -> jnp.ndarray:
        if callable(self.lr):
            return self.lr(step)
        return jnp.asarray(self.lr, jnp.float32)

    def update(
        self, grads: Any, state: AdamWState, params: Any
    ) -> Tuple[Any, AdamWState, dict]:
        """-> (new params, new state, metrics {grad_norm, lr})."""
        gnorm = global_norm(grads)
        if self.clip_norm > 0:
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)

        step = state.step + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            # decoupled weight decay: skip 1-d params (norms, biases)
            wd = self.weight_decay if p.ndim > 1 else 0.0
            return (p - lr * (delta + wd * p)).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step, mu, nu), {"grad_norm": gnorm, "lr": lr}


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def warmup_cosine(
    peak_lr: float, warmup_steps: int, total_steps: int, floor: float = 0.1
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Linear warmup -> cosine decay to floor*peak."""

    def schedule(step: jnp.ndarray) -> jnp.ndarray:
        s = step.astype(jnp.float32)
        warm = s / max(warmup_steps, 1)
        prog = jnp.clip(
            (s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * jnp.where(s < warmup_steps, warm, cos)

    return schedule
