from .checkpoint import latest_step, list_steps, prune, restore, save, save_async

__all__ = ["latest_step", "list_steps", "prune", "restore", "save", "save_async"]
