"""Fault-tolerant checkpoints: atomic manifests, async save, elastic restore.

Layout (one directory per step)::

    <root>/step_000123/
        manifest.json        tree structure + per-leaf shape/dtype/file
        leaf_00000.npy ...   one .npy per leaf (host-gathered)
    <root>/LATEST            text file naming the newest COMPLETE step dir

Guarantees
----------
* **Atomicity**: leaves are written into ``step_X.tmp`` and the directory is
  renamed into place before LATEST is updated (rename is atomic on POSIX).
  A crash mid-save leaves only a ``.tmp`` dir that restore ignores.
* **Async**: ``save_async`` snapshots to host memory synchronously (cheap)
  and writes in a background thread — the train loop never blocks on disk.
* **Elastic re-shard**: the manifest stores logical shapes only. On restore,
  leaves are placed onto the CURRENT mesh with ``jax.device_put(leaf,
  sharding)`` — so a checkpoint taken on one topology restores onto any
  other (different pod count / axis sizes), which is the re-scale path after
  node failures.
* Self-describing: restore needs no template pytree (structure serialized in
  the manifest), but accepts shardings to place leaves as they load.

Multi-host note: in a real multi-controller deployment each host gathers
only its addressable shards and process 0 writes the manifest; this
container is single-process so the gather is trivial, but the layout and
protocol are the production ones.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

_MANIFEST = "manifest.json"
_LATEST = "LATEST"


# ---------------------------------------------------------------------------
# pytree <-> flat path/leaf maps
# ---------------------------------------------------------------------------


def _flatten(tree: Any) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def _unflatten(template_paths, leaves_by_key, treedef):
    ordered = [leaves_by_key[k] for k in template_paths]
    return jax.tree_util.tree_unflatten(treedef, ordered)


# ---------------------------------------------------------------------------
# save
# ---------------------------------------------------------------------------


def save(root: str, step: int, tree: Any) -> str:
    """Synchronous atomic save. Returns the final directory path."""
    host_tree = jax.tree.map(lambda a: np.asarray(a), tree)
    return _write(root, step, host_tree)


def save_async(root: str, step: int, tree: Any) -> threading.Thread:
    """Snapshot to host memory now; write in the background."""
    host_tree = jax.tree.map(lambda a: np.asarray(a), tree)  # blocks on device
    t = threading.Thread(target=_write, args=(root, step, host_tree), daemon=True)
    t.start()
    return t


def _write(root: str, step: int, host_tree: Any) -> str:
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(host_tree)
    treedef = jax.tree_util.tree_structure(host_tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "keys": list(flat.keys()),
        "leaves": {},
    }
    for i, (key, leaf) in enumerate(flat.items()):
        fname = f"leaf_{i:05d}.npy"
        arr = np.asarray(leaf)
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    with open(os.path.join(root, _LATEST + ".tmp"), "w") as f:
        f.write(os.path.basename(final))
    os.replace(os.path.join(root, _LATEST + ".tmp"), os.path.join(root, _LATEST))
    return final


# ---------------------------------------------------------------------------
# restore
# ---------------------------------------------------------------------------


def latest_step(root: str) -> Optional[int]:
    path = os.path.join(root, _LATEST)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(root, name, _MANIFEST)):
        return None  # LATEST points at an incomplete/garbage dir
    return int(name.split("_")[-1])


def restore(
    root: str,
    template: Any,
    step: Optional[int] = None,
    shardings: Any = None,
) -> Any:
    """Restore into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching pytree of
    NamedShardings — leaves are device_put as they load (elastic re-shard:
    the target mesh need not match the one that saved).
    """
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {root}")
    d = os.path.join(root, f"step_{step:09d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)

    flat_t = _flatten(template)
    flat_s = _flatten(shardings) if shardings is not None else {}
    if set(flat_t) != set(manifest["leaves"]):
        missing = set(flat_t) ^ set(manifest["leaves"])
        raise ValueError(f"checkpoint/template structure mismatch: {sorted(missing)[:5]}")

    loaded = {}
    for key, meta in manifest["leaves"].items():
        arr = np.load(os.path.join(d, meta["file"]))
        want = flat_t[key]
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(f"{key}: shape {arr.shape} != template {want.shape}")
        arr = arr.astype(want.dtype)
        if key in flat_s and flat_s[key] is not None:
            loaded[key] = jax.device_put(arr, flat_s[key])
        else:
            loaded[key] = jax.numpy.asarray(arr)

    treedef = jax.tree_util.tree_structure(template)
    keys = list(flat_t.keys())
    return _unflatten(keys, loaded, treedef)


def list_steps(root: str):
    if not os.path.isdir(root):
        return []
    out = []
    for name in sorted(os.listdir(root)):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(root, name, _MANIFEST)):
                out.append(int(name.split("_")[-1]))
    return out


def prune(root: str, keep: int = 3):
    """Delete all but the newest ``keep`` complete checkpoints."""
    steps = list_steps(root)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(root, f"step_{s:09d}"), ignore_errors=True)
