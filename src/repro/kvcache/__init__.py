"""KV-cache substrate: dense caches with staged-ring overlay (unload path
for decode writes, instantiating the unified ``core.ring`` abstraction) and
the paged block pool backing the continuous-batching serve scheduler."""
from .paged import (
    BlockPool,
    drain_ring as drain_ring_paged,
    gather_view,
    logical_to_physical,
    make_paged_kv,
    maybe_drain as maybe_drain_paged,
    pool_rows,
    scatter_token,
    view_len,
    view_mask,
    view_rows,
)
from .staged import (
    add_ring,
    drain_ring,
    maybe_drain,
    overlay_kv,
    overlay_masks,
    overlay_step,
    ring_commit,
    ring_conflicts,
    ring_full,
    ring_state,
    ring_validity,
    stage_tile,
    strip_ring,
)

__all__ = [
    "BlockPool", "drain_ring_paged", "gather_view", "logical_to_physical",
    "make_paged_kv", "maybe_drain_paged", "pool_rows", "scatter_token",
    "view_len", "view_mask", "view_rows",
    "add_ring", "drain_ring", "maybe_drain", "overlay_kv", "overlay_masks",
    "overlay_step", "ring_commit", "ring_conflicts", "ring_full",
    "ring_state", "ring_validity", "stage_tile", "strip_ring",
]
