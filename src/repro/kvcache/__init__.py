"""KV-cache substrate: dense caches with staged-ring overlay (unload path
for decode writes, instantiating the unified ``core.ring`` abstraction) and
a paged pool with page-frequency monitoring."""
from .paged import (
    PagedCache,
    PageMonitor,
    allocate_pages,
    direct_insert,
    gather_kv,
    make_paged_cache,
    write_destination,
)
from .staged import (
    add_ring,
    drain_ring,
    maybe_drain,
    overlay_kv,
    overlay_masks,
    overlay_step,
    ring_commit,
    ring_conflicts,
    ring_full,
    ring_state,
    ring_validity,
    stage_tile,
    strip_ring,
)

__all__ = [
    "PagedCache", "PageMonitor", "allocate_pages", "direct_insert",
    "gather_kv", "make_paged_cache", "write_destination",
    "add_ring", "drain_ring", "maybe_drain", "overlay_kv", "overlay_masks",
    "overlay_step", "ring_commit", "ring_conflicts", "ring_full",
    "ring_state", "ring_validity", "stage_tile", "strip_ring",
]
