"""KV-cache substrate: dense caches with staged-ring overlay (unload path
for decode writes) and a paged pool with page-frequency monitoring."""
from .paged import (
    PagedCache,
    PageMonitor,
    allocate_pages,
    direct_insert,
    gather_kv,
    make_paged_cache,
    write_destination,
)
from .staged import (
    add_ring,
    drain_ring,
    maybe_drain,
    overlay_kv,
    overlay_masks,
    ring_append,
    ring_commit,
    ring_full,
    strip_ring,
)

__all__ = [
    "PagedCache", "PageMonitor", "allocate_pages", "direct_insert",
    "gather_kv", "make_paged_cache", "write_destination",
    "add_ring", "drain_ring", "maybe_drain", "overlay_kv", "overlay_masks",
    "ring_append", "ring_commit", "ring_full", "strip_ring",
]
