"""Paged KV cache: a global pool of fixed-size blocks backing EVERY
decode-time KV write of the continuous-batching serve scheduler.

Layout (vLLM-style, adapted to TPU: blocks are dense [page_size, H, Dh]
tiles so attention gathers whole blocks, never elements):

* ``pages_k`` / ``pages_v``  [L, n_blocks, page_size, H, Dh] — the physical
  pool, shared by every serving slot.
* ``page_table``             int32 [n_slots, max_pages] — physical block
  backing each slot's logical page (-1 = unallocated).
* A slot's *logical* row ``r`` lives at physical pool row
  ``page_table[slot, r // page_size] * page_size + r % page_size``.

Allocation is a host-side free-list (:class:`BlockPool`): the scheduler
allocates a slot's blocks at ADMISSION and frees them at RETIREMENT,
between scan segments — so inside the jitted decode scan the mapping is
a fixed-shape table lookup, never a data-dependent allocation.

The WRITE side is where the paper lands: inserting a token's (k, v) at an
arbitrary physical pool row is the RDMA-write analogue (random destination
page). Both paths go through this module's destination mapping:

* DIRECT (offload): scatter the tile straight to its physical row.
* STAGED (unload):  append to the per-slot ring overlay (``ring_k`` /
  ``ring_v`` / ``ring_pos`` / ``ring_fill`` keys on the same cache dict);
  attention reads pool-view ∪ ring; drains bulk-copy the ring into the
  pool through ``core.ring.scatter_rows`` (-> the ``staged_scatter``
  Pallas kernel on TPU). Ring entries record LOGICAL rows — physical
  rows are resolved through the page table at drain time, so a drain
  stays correct even though the pool is shared across slots (block
  ownership keeps drain destinations unique across slots).

The decision module's *region* for a write is its physical BLOCK id —
interleaved multi-slot traffic therefore hits a genuinely shared region
universe, exactly the mixed write stream the paper's monitor sees.
"""
from __future__ import annotations

import os
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core import ring as R

PagedKV = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# Host-side block allocator
# ---------------------------------------------------------------------------


class BlockPool:
    """Free-list allocator over the physical block pool (host side).

    LIFO free list: the most recently freed blocks are handed out first
    (hot pool rows stay hot). ``owner[b]`` tracks which slot holds block
    ``b`` (-1 = free) — the scheduler-invariant tests audit it directly.
    """

    def __init__(self, n_blocks: int):
        self.n_blocks = n_blocks
        self._free: List[int] = list(range(n_blocks - 1, -1, -1))
        self.owner = np.full((n_blocks,), -1, np.int32)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, slot: int, n: int) -> Optional[np.ndarray]:
        """Pop ``n`` blocks for ``slot``; None (no partial alloc) if the
        pool can't cover the request."""
        if n > len(self._free):
            return None
        blocks = np.asarray([self._free.pop() for _ in range(n)], np.int32)
        self.owner[blocks] = slot
        return blocks

    def free_slot(self, slot: int) -> np.ndarray:
        """Return all of ``slot``'s blocks to the free list."""
        blocks = np.flatnonzero(self.owner == slot).astype(np.int32)
        for b in blocks:
            self._free.append(int(b))
        self.owner[blocks] = -1
        return blocks


# ---------------------------------------------------------------------------
# Device cache construction / addressing
# ---------------------------------------------------------------------------


def make_paged_kv(
    n_layers: int,
    n_blocks: int,
    page_size: int,
    n_slots: int,
    max_pages: int,
    h: int,
    dh: int,
    dtype=jnp.float32,
    ring_size: int = 0,
) -> PagedKV:
    """Paged cache dict; ``ring_size > 0`` attaches the staging overlay."""
    cache = {
        "pages_k": jnp.zeros((n_layers, n_blocks, page_size, h, dh), dtype),
        "pages_v": jnp.zeros((n_layers, n_blocks, page_size, h, dh), dtype),
        "page_table": jnp.full((n_slots, max_pages), -1, jnp.int32),
    }
    if ring_size:
        cache["ring_k"] = jnp.zeros((n_layers, n_slots, ring_size, h, dh), dtype)
        cache["ring_v"] = jnp.zeros_like(cache["ring_k"])
        # staged entries record LOGICAL rows (-1 = empty); the page table
        # resolves them to physical pool rows at drain time
        cache["ring_pos"] = jnp.full((n_slots, ring_size), -1, jnp.int32)
        cache["ring_fill"] = jnp.zeros((), jnp.int32)
    return cache


def has_ring(cache: PagedKV) -> bool:
    return "ring_pos" in cache


def pool_rows(cache: PagedKV) -> int:
    """Total physical rows (the out-of-range write sentinel)."""
    nb, ps = cache["pages_k"].shape[1:3]
    return nb * ps


def view_len(cache: PagedKV) -> int:
    """Logical rows per slot (max_pages * page_size)."""
    return cache["page_table"].shape[1] * cache["pages_k"].shape[2]


def logical_to_physical(cache: PagedKV, rows: jnp.ndarray) -> jnp.ndarray:
    """Per-slot logical row -> physical pool row. ``rows`` int32 [n_slots].

    Rows on unallocated pages (or negative sentinels) map to the
    out-of-range sentinel ``pool_rows`` so downstream scatters DROP them —
    a retired or empty slot can never write."""
    ps = cache["pages_k"].shape[2]
    n_slots = cache["page_table"].shape[0]
    safe = jnp.clip(rows, 0, view_len(cache) - 1)
    block = cache["page_table"][jnp.arange(n_slots), safe // ps]
    phys = block * ps + safe % ps
    ok = (rows >= 0) & (rows < view_len(cache)) & (block >= 0)
    return jnp.where(ok, phys, pool_rows(cache)).astype(jnp.int32)


def logical_to_physical_many(cache: PagedKV, rows: jnp.ndarray) -> jnp.ndarray:
    """Per-slot logical rows -> physical pool rows, ``rows`` int32
    [n_slots, C] (the chunk generalization of :func:`logical_to_physical`;
    column ``j`` of slot ``b`` resolves through slot ``b``'s page table).
    Invalid rows (negative sentinel, out of view, unallocated page) map to
    the out-of-range sentinel ``pool_rows`` so scatters DROP them."""
    ps = cache["pages_k"].shape[2]
    n_slots = cache["page_table"].shape[0]
    safe = jnp.clip(rows, 0, view_len(cache) - 1)
    block = cache["page_table"][jnp.arange(n_slots)[:, None], safe // ps]
    phys = block * ps + safe % ps
    ok = (rows >= 0) & (rows < view_len(cache)) & (block >= 0)
    return jnp.where(ok, phys, pool_rows(cache)).astype(jnp.int32)


def view_rows(cache: PagedKV) -> jnp.ndarray:
    """int32 [n_slots, V]: physical pool row backing every logical row
    (clamped to 0 where unallocated — mask with :func:`view_mask`)."""
    ps = cache["pages_k"].shape[2]
    table = cache["page_table"]
    base = jnp.maximum(table, 0) * ps  # [n_slots, max_pages]
    rows = base[:, :, None] + jnp.arange(ps)[None, None, :]
    return rows.reshape(table.shape[0], -1).astype(jnp.int32)


class StepPlan(NamedTuple):
    """Page-table-derived read-path products, hoisted ONCE per segment.

    The page table only changes host-side between scan segments (allocation
    at admission, frees at retirement), so everything derived from it —
    the logical->physical row map the reference gather uses, the clamped
    block table the fused kernel's scalar prefetch walks, and the
    page-allocated mask — is loop-invariant across a whole segment, not
    just across layers. The scheduler builds one plan per segment and
    threads it through every decode step.
    """

    view_ids: jnp.ndarray   # int32 [n_slots, V] physical row per logical row
    blocks: jnp.ndarray     # int32 [n_slots, P] clamped physical block ids
    allocated: jnp.ndarray  # bool [n_slots, V] page-allocated per logical row


def kernel_blocks(cache: PagedKV) -> jnp.ndarray:
    """int32 [n_slots, max_pages]: the fused kernel's scalar-prefetch
    operand — physical block ids, clamped to 0 where unallocated. Clamped
    entries walk block 0 and read the SAME garbage ``gather_view`` gathers
    through the clamped :func:`view_rows`, and the view mask hides it in
    both implementations, so fused and reference agree even on dead
    slots."""
    return jnp.maximum(cache["page_table"], 0).astype(jnp.int32)


def step_plan(cache: PagedKV) -> StepPlan:
    """Build the per-segment :class:`StepPlan` (see its docstring)."""
    ps = cache["pages_k"].shape[2]
    return StepPlan(
        view_ids=view_rows(cache),
        blocks=kernel_blocks(cache),
        allocated=jnp.repeat(cache["page_table"] >= 0, ps, axis=1),
    )


def view_mask_from(allocated: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
    """:func:`view_mask` from a hoisted ``StepPlan.allocated``."""
    logical = jnp.arange(allocated.shape[1])[None, :]
    return (logical <= pos[:, None]) & allocated


def view_mask(cache: PagedKV, pos: jnp.ndarray) -> jnp.ndarray:
    """bool [n_slots, V]: logical rows holding live KV once row ``pos``
    is written this step (linear addressing: rows 0..pos on allocated
    pages)."""
    ps = cache["pages_k"].shape[2]
    allocated = jnp.repeat(cache["page_table"] >= 0, ps, axis=1)
    return view_mask_from(allocated, pos)


def gather_view(pages_l: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
    """One layer's per-slot contiguous KV view.

    pages_l [n_blocks, ps, H, Dh], rows int32 [n_slots, V] ->
    [n_slots, V, H, Dh]. Rows of unallocated pages gather block 0 garbage;
    the attention mask (:func:`view_mask`) excludes them."""
    flat = pages_l.reshape((-1,) + pages_l.shape[2:])
    return flat[rows]


def scatter_token(
    pages_l: jnp.ndarray,   # [n_blocks, ps, H, Dh]
    dest: jnp.ndarray,      # int32 [n_slots] physical rows (sentinel drops)
    tile: jnp.ndarray,      # [n_slots, H, Dh]
) -> jnp.ndarray:
    """Direct (offload-path) write of one decode step's tiles."""
    flat = pages_l.reshape((-1,) + pages_l.shape[2:])
    flat = flat.at[dest].set(tile.astype(flat.dtype), mode="drop")
    return flat.reshape(pages_l.shape)


def scatter_chunk(
    pages_l: jnp.ndarray,   # [n_blocks, ps, H, Dh]
    dest: jnp.ndarray,      # int32 [n_slots, C] physical rows (sentinel drops)
    tiles: jnp.ndarray,     # [n_slots, C, H, Dh]
) -> jnp.ndarray:
    """Direct (offload-path) bulk write of one mixed-phase step's tiles —
    the prefill-chunk analogue of :func:`scatter_token`. Destinations are
    unique across slots (block ownership) and within a chunk (consecutive
    logical rows), so the scatter never collides."""
    flat = pages_l.reshape((-1,) + pages_l.shape[2:])
    flat = flat.at[dest.reshape(-1)].set(
        tiles.reshape((-1,) + tiles.shape[2:]).astype(flat.dtype),
        mode="drop")
    return flat.reshape(pages_l.shape)


# ---------------------------------------------------------------------------
# Staging-ring overlay (instantiation of core.ring, logical-row keys)
# ---------------------------------------------------------------------------


def ring_state(cache: PagedKV) -> R.RingState:
    """Dense-mode ring bookkeeping view (``core.ring.dense_state`` on this
    overlay's logical-row metadata — cf. ``kvcache.staged.ring_state``)."""
    return R.dense_state(cache["ring_pos"], cache["ring_fill"])


def ring_validity(cache: PagedKV) -> jnp.ndarray:
    return ring_state(cache).live


def ring_full(cache: PagedKV) -> jnp.ndarray:
    return R.full(ring_state(cache), wrap=False)


def ring_conflicts(cache: PagedKV, pos: jnp.ndarray) -> jnp.ndarray:
    """True if this step's logical destinations collide with pending staged
    entries of the same slot (drain first: keeps drain rows unique)."""
    return R.conflicts(ring_state(cache), (cache["ring_pos"],),
                       (pos[:, None],))


def stage_tile(plane: jnp.ndarray, tile: jnp.ndarray,
               cur: jnp.ndarray) -> jnp.ndarray:
    """Append one layer's tiles [n_slots, H, Dh] at ring column ``cur``."""
    return R.push_column(plane, cur, tile, axis=1)


def ring_commit(cache: PagedKV, pos: jnp.ndarray,
                unload_mask: jnp.ndarray) -> PagedKV:
    """Metadata half of the append: record logical rows (-1 where the slot
    wrote direct or is retired) at the cursor, advance it."""
    cur = cache["ring_fill"]
    rows = jnp.where(unload_mask, pos, -1).astype(jnp.int32)
    cache = dict(cache)
    cache["ring_pos"] = R.push_column(cache["ring_pos"], cur, rows)
    cache["ring_fill"] = cur + 1
    return cache


def overlay_step_parts(
    cache: PagedKV,
    vmask: jnp.ndarray,        # bool [n_slots, V] view validity after write
    pos: jnp.ndarray,          # int32 [n_slots] this step's logical rows
    unload_mask: jnp.ndarray,  # bool [n_slots] True = stage
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-step overlay bookkeeping, kept as SEPARATE sources.

    Returns (view_ok [n_slots, V] pool-view validity with staged rows
    shadowed out, ring_ok [n_slots, R] ring-lane validity including this
    step's append, cur — the ring column this step appends to). The fused
    kernel consumes the two masks directly (pool walk + ring lanes as a
    second softmax source); the reference path concatenates them
    (:func:`overlay_step`) — same booleans either way, so mask parity
    between the implementations is by construction.
    """
    b, v = vmask.shape
    r = cache["ring_pos"].shape[1]
    cur = cache["ring_fill"]
    ring_valid = ring_validity(cache) | (
        (jnp.arange(r)[None, :] == cur) & unload_mask[:, None]
    )
    shadowed = R.shadow_mask(
        ring_validity(cache), cache["ring_pos"], v,
        extra_rows=jnp.where(unload_mask, pos, v),
    )
    return vmask & ~shadowed, ring_valid, cur


def overlay_step(
    cache: PagedKV,
    vmask: jnp.ndarray,        # bool [n_slots, V] view validity after write
    pos: jnp.ndarray,          # int32 [n_slots] this step's logical rows
    unload_mask: jnp.ndarray,  # bool [n_slots] True = stage
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-step overlay bookkeeping for ``decode_step_paged``.

    Returns (full_mask [n_slots, V+R] attention validity over view ∪ ring,
    cur — the ring column this step appends to). The authoritative value
    for a staged entry lives in the RING until drained, so its logical row
    is shadowed out of the view mask.
    """
    view_ok, ring_valid, cur = overlay_step_parts(cache, vmask, pos,
                                                  unload_mask)
    full_mask = jnp.concatenate([view_ok, ring_valid], axis=1)
    return full_mask, cur


def view_chunk_mask(cache: PagedKV, positions: jnp.ndarray) -> jnp.ndarray:
    """bool [n_slots, C, V]: per-query view validity for a mixed-phase
    chunk step. ``positions`` int32 [n_slots, C] — query ``j`` of slot
    ``b`` sits at logical row ``positions[b, j]``; linear addressing means
    a view row is causally visible when its logical id is <= the query's
    position, and attendable only on an allocated page (this step's chunk
    rows are scattered into the pool BEFORE the gather, so in-chunk causal
    visibility falls out of the same rule)."""
    ps = cache["pages_k"].shape[2]
    allocated = jnp.repeat(cache["page_table"] >= 0, ps, axis=1)
    return view_chunk_mask_from(allocated, positions)


def view_chunk_mask_from(allocated: jnp.ndarray,
                         positions: jnp.ndarray) -> jnp.ndarray:
    """:func:`view_chunk_mask` from a hoisted ``StepPlan.allocated``."""
    rows = jnp.arange(allocated.shape[1])[None, None, :]
    return (rows <= positions[:, :, None]) & allocated[:, None, :]


def overlay_chunk_parts(
    cache: PagedKV,
    positions: jnp.ndarray,    # int32 [n_slots, C] per-query logical rows
    unload_mask: jnp.ndarray,  # bool [n_slots] True = column-0 write stages
    allocated: Optional[jnp.ndarray] = None,  # hoisted StepPlan.allocated
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Chunked analogue of :func:`overlay_step_parts`.

    Returns (view_ok [n_slots, C, V], ring_ok [n_slots, R] — per-lane, NOT
    broadcast over C: a slot's pending ring entries always hold rows
    strictly below its current position (conflict-forced drains), so ring
    validity needs no per-query causal term — and cur, the ring column this
    step appends to).
    """
    r = cache["ring_pos"].shape[1]
    cur = cache["ring_fill"]
    live = ring_validity(cache)
    ring_valid = live | (
        (jnp.arange(r)[None, :] == cur) & unload_mask[:, None]
    )
    v = view_len(cache)
    shadowed = R.shadow_mask(
        live, cache["ring_pos"], v,
        extra_rows=jnp.where(unload_mask, positions[:, 0], v),
    )
    if allocated is None:
        vmask = view_chunk_mask(cache, positions)
    else:
        vmask = view_chunk_mask_from(allocated, positions)
    return vmask & ~shadowed[:, None, :], ring_valid, cur


def overlay_chunk(
    cache: PagedKV,
    positions: jnp.ndarray,    # int32 [n_slots, C] per-query logical rows
    unload_mask: jnp.ndarray,  # bool [n_slots] True = column-0 write stages
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mixed-phase generalization of :func:`overlay_step`.

    Returns (full_mask bool [n_slots, C, V+R] attention validity over
    view ∪ ring, cur — the ring column this step appends to). Only the
    scattered column-0 (decode-phase) write may stage; prefill chunks are
    bulk/direct, and a prefilling slot's ring lane is empty (lanes drain at
    every segment boundary, before the slot could have been admitted).
    """
    view_ok, ring_valid, cur = overlay_chunk_parts(cache, positions,
                                                   unload_mask)
    c = positions.shape[1]
    r = ring_valid.shape[1]
    ring_ok = jnp.broadcast_to(ring_valid[:, None, :],
                               (positions.shape[0], c, r))
    return jnp.concatenate([view_ok, ring_ok], axis=2), cur


def _auto_drain_kernel() -> bool:
    """Default kernel selection for :func:`drain_ring`.

    The paged pool layout ALWAYS satisfies the ``staged_scatter``
    preconditions (full-row entries, drain-unique destinations), so the
    kernel is selected automatically wherever it is the fast path: any
    non-CPU backend. On CPU the jnp oracle is the fast path, but setting
    ``REPRO_DRAIN_KERNEL=1`` forces the kernel (interpret mode) so CI's
    CPU serving jobs exercise the real drain kernel end to end;
    ``REPRO_DRAIN_KERNEL=0`` forces the oracle everywhere.
    """
    env = os.environ.get("REPRO_DRAIN_KERNEL")
    if env is not None:
        return env not in ("", "0")
    return jax.default_backend() != "cpu"


def drain_ring(cache: PagedKV, use_kernel: Optional[bool] = None) -> PagedKV:
    """Bulk-copy all staged entries into the pool, empty the ring.

    Per layer, ALL slots' entries flatten into ONE entry list (``core.ring.
    merge_lanes``) and land with a single ``scatter_rows`` call — block
    ownership makes destinations unique across slots, conflict-forced
    drains make them unique within a slot (the ``staged_scatter``
    precondition). ``use_kernel=None`` (the default) selects the kernel
    automatically (:func:`_auto_drain_kernel`) — callers no longer have to
    opt in for serving to exercise the drain kernel."""
    if use_kernel is None:
        use_kernel = _auto_drain_kernel()
    l, b, r, h, dh = cache["ring_k"].shape
    n_phys = pool_rows(cache)
    # resolve logical -> physical per ring column, then flatten lanes
    phys = jax.vmap(lambda rows: logical_to_physical(cache, rows),
                    in_axes=1, out_axes=1)(cache["ring_pos"])
    rows, ok = R.merge_lanes(ring_state(cache), phys)
    # logical_to_physical maps every invalid row to exactly n_phys, which
    # scatter_rows drops — no re-clamp needed

    def drain_layer(pages_l, staging_l):
        flat = pages_l.reshape(n_phys, h * dh)
        out = R.scatter_rows(flat, staging_l.reshape(b * r, h * dh),
                             rows, ok, use_kernel=use_kernel)
        return out.reshape(pages_l.shape)

    new_k = jax.vmap(drain_layer)(cache["pages_k"], cache["ring_k"])
    new_v = jax.vmap(drain_layer)(cache["pages_v"], cache["ring_v"])
    return dict(
        cache,
        pages_k=new_k,
        pages_v=new_v,
        ring_pos=jnp.full_like(cache["ring_pos"], -1),
        ring_fill=jnp.zeros_like(cache["ring_fill"]),
    )


def maybe_drain(
    cache: PagedKV,
    use_kernel: Optional[bool] = None,
    incoming_pos: Optional[jnp.ndarray] = None,
) -> Tuple[PagedKV, jnp.ndarray]:
    """Fixed-shape conditional drain: ring full OR incoming logical rows
    conflict with pending entries. Returns (cache, drained bool)."""
    due = ring_full(cache)
    if incoming_pos is not None:
        due = due | ring_conflicts(cache, incoming_pos)
    cache = lax.cond(
        due,
        lambda c: drain_ring(c, use_kernel=use_kernel),
        lambda c: dict(c),
        cache,
    )
    return cache, due
