"""Paged KV cache with uRDMA write-engine integration.

Serving-grade cache layout: a global pool of fixed-size pages plus a per-
sequence page table (vLLM-style, adapted to TPU: pages are dense
[page_size, H, Dh] tiles so attention gathers whole pages, never elements).

The WRITE side is where the paper lands: inserting a token's (k, v) into
page ``page_table[seq, pos // page_size]`` is a write to an arbitrary
destination page — direct scatter (offload) vs staging ring + bulk drain
(unload), routed per-write by the decision module over page-frequency
counters. This module provides the PAGE-GRANULAR destination mapping and
the monitor plumbing; the ring mechanics are shared with
``repro.kvcache.staged``.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..core.monitor import ExactMonitor, MonitorState


class PagedCache(NamedTuple):
    pages_k: jnp.ndarray     # [n_pages, page_size, H, Dh]
    pages_v: jnp.ndarray     # [n_pages, page_size, H, Dh]
    page_table: jnp.ndarray  # int32 [B, max_pages_per_seq]
    lengths: jnp.ndarray     # int32 [B] tokens written per sequence
    n_allocated: jnp.ndarray  # int32 scalar — pages handed out so far


def make_paged_cache(
    n_pages: int, page_size: int, h: int, dh: int, batch: int,
    max_pages_per_seq: int, dtype=jnp.float32,
) -> PagedCache:
    return PagedCache(
        pages_k=jnp.zeros((n_pages, page_size, h, dh), dtype),
        pages_v=jnp.zeros((n_pages, page_size, h, dh), dtype),
        page_table=jnp.full((batch, max_pages_per_seq), -1, jnp.int32),
        lengths=jnp.zeros((batch,), jnp.int32),
        n_allocated=jnp.zeros((), jnp.int32),
    )


def allocate_pages(cache: PagedCache, seq_ids: jnp.ndarray) -> PagedCache:
    """Give each listed sequence a fresh page if its current one is full.

    Bump allocation from the global pool (a real deployment frees pages on
    sequence retirement; eviction policy is out of scope here).
    """
    ps = cache.pages_k.shape[1]
    need = (cache.lengths[seq_ids] % ps == 0)
    rank = jnp.cumsum(need.astype(jnp.int32)) - 1
    new_page = jnp.where(need, cache.n_allocated + rank, -1)
    slot = cache.lengths[seq_ids] // ps
    table = cache.page_table.at[seq_ids, slot].set(
        jnp.where(need, new_page, cache.page_table[seq_ids, slot]), mode="drop"
    )
    return cache._replace(
        page_table=table,
        n_allocated=cache.n_allocated + jnp.sum(need.astype(jnp.int32)),
    )


def write_destination(cache: PagedCache, seq_ids: jnp.ndarray):
    """(page id, row within page) for each sequence's next token."""
    ps = cache.pages_k.shape[1]
    pos = cache.lengths[seq_ids]
    page = cache.page_table[seq_ids, pos // ps]
    return page, pos % ps


def direct_insert(
    cache: PagedCache,
    seq_ids: jnp.ndarray,   # int32 [n]
    k_new: jnp.ndarray,     # [n, H, Dh]
    v_new: jnp.ndarray,
) -> PagedCache:
    """Offload path: scatter each token straight into its page."""
    page, row = write_destination(cache, seq_ids)
    pk = cache.pages_k.at[page, row].set(k_new.astype(cache.pages_k.dtype), mode="drop")
    pv = cache.pages_v.at[page, row].set(v_new.astype(cache.pages_v.dtype), mode="drop")
    lengths = cache.lengths.at[seq_ids].add(1)
    return cache._replace(pages_k=pk, pages_v=pv, lengths=lengths)


def gather_kv(cache: PagedCache, seq_id: jnp.ndarray, max_len: int):
    """Assemble one sequence's [max_len, H, Dh] kv view + validity mask."""
    ps = cache.pages_k.shape[1]
    n_slots = max_len // ps
    pages = cache.page_table[seq_id, :n_slots]  # [n_slots]
    k = cache.pages_k[jnp.maximum(pages, 0)]    # [n_slots, ps, H, Dh]
    v = cache.pages_v[jnp.maximum(pages, 0)]
    k = k.reshape(max_len, *k.shape[2:])
    v = v.reshape(max_len, *v.shape[2:])
    valid = (jnp.arange(max_len) < cache.lengths[seq_id]) & jnp.repeat(
        pages >= 0, ps
    )
    return k, v, valid


class PageMonitor(NamedTuple):
    """Page-frequency counters — the decision module's monitor for KV writes."""

    state: MonitorState

    @staticmethod
    def create(n_pages: int) -> "PageMonitor":
        return PageMonitor(ExactMonitor(n_pages).init())

    def update(self, n_pages: int, pages: jnp.ndarray) -> "PageMonitor":
        mon = ExactMonitor(n_pages)
        return PageMonitor(mon.update(self.state, pages))

    def counts(self) -> jnp.ndarray:
        return self.state.counts
