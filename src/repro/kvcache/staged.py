"""Staged KV-cache writes: the unload path for decode-time KV insertion.

Decode writes one (k, v) tile per layer per step into an arbitrary slot of a
large cache — the RDMA-write analogue (random destination page). Three
write paths, mirroring the paper:

* DIRECT (offload): ``transformer.direct_kv_write`` — per-sequence dynamic
  scatter straight into the big cache. Fine when slots are "hot" (the same
  pages being appended step after step keep their translations/layout warm);
  on TPU each step costs a scattered dynamic-update-slice over the huge
  cache buffer.
* STAGED (unload): append the new tiles into a small RING overlay
  [L, B, R, H, Dh] (sequential, dense, VMEM-resident-scale). Attention reads
  cache ∪ ring (concatenated along the sequence axis with a validity mask —
  no correctness gap while entries are staged). Every R steps the ring is
  DRAINED into the main cache with one regular bulk copy
  (``kernels.staged_scatter``) — R scattered writes become 1 dense copy.
* ADAPTIVE: the decision module (page-frequency counters over destination
  pages) picks per-sequence: hot pages direct, cold staged.

State lives in the cache pytree so the whole thing jits and scans.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels import staged_scatter

Cache = Dict[str, jnp.ndarray]


def add_ring(cache: Cache, ring_size: int) -> Cache:
    """Extend a dense KV cache {k, v: [L, B, S, H, Dh]} with a staging ring."""
    l, b, s, h, dh = cache["k"].shape
    r = ring_size
    return dict(
        cache,
        ring_k=jnp.zeros((l, b, r, h, dh), cache["k"].dtype),
        ring_v=jnp.zeros((l, b, r, h, dh), cache["v"].dtype),
        ring_slot=jnp.full((b, r), -1, jnp.int32),  # main-cache slot per entry
        ring_fill=jnp.zeros((), jnp.int32),         # entries staged so far
    )


def strip_ring(cache: Cache) -> Cache:
    return {k: v for k, v in cache.items() if not k.startswith("ring_")}


def ring_append(cache: Cache, layer_kv: Tuple[jnp.ndarray, jnp.ndarray],
                layer_idx: jnp.ndarray, slots: jnp.ndarray) -> Cache:
    """Append one layer's new KV tile at the ring cursor (during scan,
    ``layer_idx`` selects the ring plane; cursor advances once per step via
    ``ring_commit``)."""
    k_new, v_new = layer_kv  # [B, 1, H, Dh]
    cur = cache["ring_fill"]
    cache = dict(cache)
    cache["ring_k"] = lax.dynamic_update_slice(
        cache["ring_k"], k_new[None], (layer_idx, 0, cur, 0, 0)
    )
    cache["ring_v"] = lax.dynamic_update_slice(
        cache["ring_v"], v_new[None], (layer_idx, 0, cur, 0, 0)
    )
    return cache


def ring_commit(cache: Cache, slots: jnp.ndarray) -> Cache:
    """Record destination slots for this step's entries and advance cursor."""
    cur = cache["ring_fill"]
    cache = dict(cache)
    cache["ring_slot"] = lax.dynamic_update_slice(
        cache["ring_slot"], slots[:, None], (0, cur)
    )
    cache["ring_fill"] = cur + 1
    return cache


def ring_full(cache: Cache) -> jnp.ndarray:
    return cache["ring_fill"] >= cache["ring_slot"].shape[1]


def drain_ring(cache: Cache, use_kernel: bool = True) -> Cache:
    """Bulk-copy all staged entries to their main-cache slots, empty ring.

    The copy is the staged_scatter drain: per (layer, batch), ring rows
    [R, H*Dh] land at rows ``ring_slot[b]`` of the cache's [S, H*Dh] view.
    """
    l, b, r, h, dh = cache["ring_k"].shape
    s = cache["k"].shape[2]
    valid = (jnp.arange(r) < cache["ring_fill"])[None, :] & (cache["ring_slot"] >= 0)

    def drain_one(dest, staging, slots, ok):
        # dest [S, H, Dh]; staging [R, H, Dh]
        if use_kernel:
            out = staged_scatter(
                dest.reshape(s, h * dh), staging.reshape(r, h * dh), slots, ok
            )
            return out.reshape(s, h, dh)
        idx = jnp.where(ok, slots, s)
        return dest.at[idx].set(staging, mode="drop", unique_indices=True)

    def drain_layer(dest_l, staging_l):
        return jax.vmap(drain_one, in_axes=(0, 0, 0, 0))(
            dest_l, staging_l, cache["ring_slot"], valid
        )

    new_k = jax.vmap(drain_layer)(cache["k"], cache["ring_k"])
    new_v = jax.vmap(drain_layer)(cache["v"], cache["ring_v"])
    return dict(
        cache,
        k=new_k,
        v=new_v,
        ring_slot=jnp.full_like(cache["ring_slot"], -1),
        ring_fill=jnp.zeros((), jnp.int32),
    )


def maybe_drain(cache: Cache, use_kernel: bool = False) -> Cache:
    """Fixed-shape conditional drain (serve-loop safe)."""
    return lax.cond(
        ring_full(cache),
        lambda c: drain_ring(c, use_kernel=use_kernel),
        lambda c: dict(c),
        cache,
    )


def overlay_masks(cache: Cache, base_mask: jnp.ndarray) -> jnp.ndarray:
    """Validity mask for attention over [cache ∪ ring].

    base_mask: bool [B, S] for the main cache. Staged entries are valid up
    to ring_fill; their main-cache slots must be EXCLUDED from the base mask
    (the authoritative value lives in the ring until drained).
    """
    b, s = base_mask.shape
    r = cache["ring_slot"].shape[1]
    fill = cache["ring_fill"]
    ring_valid = (jnp.arange(r)[None, :] < fill) & (cache["ring_slot"] >= 0)
    # exclude undrained slots from the main mask
    slot_oh = jax.nn.one_hot(
        jnp.where(ring_valid, cache["ring_slot"], s), s + 1, dtype=jnp.bool_
    )[..., :s]  # [B, R, S]
    shadowed = jnp.any(slot_oh, axis=1)
    return jnp.concatenate([base_mask & ~shadowed, ring_valid], axis=1)


def overlay_kv(cache: Cache, layer_k: jnp.ndarray, layer_v: jnp.ndarray,
               ring_k: jnp.ndarray, ring_v: jnp.ndarray):
    """Concatenate main-cache and ring KV along the sequence axis."""
    return (
        jnp.concatenate([layer_k, ring_k], axis=1),
        jnp.concatenate([layer_v, ring_v], axis=1),
    )
