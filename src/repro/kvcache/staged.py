"""Staged KV-cache writes: the unload path for decode-time KV insertion,
built on the unified ring abstraction in ``repro.core.ring`` (the flat
``RemoteWriteEngine`` ring in ``core.unload`` is the other instantiation —
see DESIGN.md §1).

Decode writes one (k, v) tile per layer per step into an arbitrary slot of a
large cache — the RDMA-write analogue (random destination page). Three
write paths, mirroring the paper:

* DIRECT (offload): ``transformer.direct_kv_write`` — per-sequence dynamic
  scatter straight into the big cache. Fine when slots are "hot" (the same
  pages being appended step after step keep their translations/layout warm);
  on TPU each step costs a scattered dynamic-update-slice over the huge
  cache buffer.
* STAGED (unload): append the new tiles into a small RING overlay
  [L, B, R, H, Dh] (sequential, dense, VMEM-resident-scale). Attention reads
  cache ∪ ring (concatenated along the sequence axis with a validity mask —
  no correctness gap while entries are staged). Every R steps the ring is
  DRAINED into the main cache with one regular bulk copy
  (``core.ring.scatter_rows`` -> the ``staged_scatter`` Pallas kernel) —
  R scattered writes become 1 dense copy.
* ADAPTIVE: the decision module (page-frequency counters over destination
  pages) picks per-sequence: hot pages direct, cold staged.

State lives in the cache pytree (``ring_k``/``ring_v`` payload planes,
``ring_slot`` destination metadata, ``ring_fill`` cursor — names are stable
for the sharding rules and checkpoints) but ALL ring logic — validity,
overflow, conflict-forced drains, the drain copy — delegates to
``core.ring`` on a :func:`ring_state` view. The whole thing jits and scans.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..core import ring as R

Cache = Dict[str, jnp.ndarray]


def add_ring(cache: Cache, ring_size: int) -> Cache:
    """Extend a dense KV cache {k, v: [L, B, S, H, Dh]} with a staging ring."""
    l, b, s, h, dh = cache["k"].shape
    r = ring_size
    return dict(
        cache,
        ring_k=jnp.zeros((l, b, r, h, dh), cache["k"].dtype),
        ring_v=jnp.zeros((l, b, r, h, dh), cache["v"].dtype),
        ring_slot=jnp.full((b, r), -1, jnp.int32),  # main-cache slot per entry
        ring_fill=jnp.zeros((), jnp.int32),         # entries staged so far
    )


def strip_ring(cache: Cache) -> Cache:
    return {k: v for k, v in cache.items() if not k.startswith("ring_")}


def ring_state(cache: Cache) -> R.RingState:
    """Shared-bookkeeping view of the cache's ring fields (dense mode)."""
    return R.dense_state(cache["ring_slot"], cache["ring_fill"])


def ring_validity(cache: Cache) -> jnp.ndarray:
    """bool [B, R]: ring entries holding live (undrained) KV."""
    return ring_state(cache).live


def ring_full(cache: Cache) -> jnp.ndarray:
    return R.full(ring_state(cache), wrap=False)


def ring_conflicts(cache: Cache, slots: jnp.ndarray) -> jnp.ndarray:
    """True if this step's destination ``slots`` [B] collide with a pending
    staged entry for the same sequence — the drain must run first so the
    drain batch keeps unique destination rows (the ``scatter_rows`` /
    ``staged_scatter`` precondition) and program order per slot holds."""
    return R.conflicts(ring_state(cache), (cache["ring_slot"],),
                       (slots[:, None],))


def stage_tile(plane: jnp.ndarray, tile: jnp.ndarray,
               cur: jnp.ndarray) -> jnp.ndarray:
    """Append one layer's new KV tile [B, 1, H, Dh] at ring column ``cur``
    of a per-layer ring plane [B, R, H, Dh] (used inside the layer scan)."""
    return R.push_column(plane, cur, tile[:, 0], axis=1)


def ring_commit(cache: Cache, slots: jnp.ndarray,
                unload_mask: jnp.ndarray) -> Cache:
    """Record this step's destination slots (-1 for sequences that wrote
    direct) at the cursor and advance it. The payload tiles were staged per
    layer by ``stage_tile``; this is the metadata half of the append."""
    cur = cache["ring_fill"]
    rows = jnp.where(unload_mask, slots, -1).astype(jnp.int32)
    cache = dict(cache)
    cache["ring_slot"] = R.push_column(cache["ring_slot"], cur, rows)
    cache["ring_fill"] = cur + 1
    return cache


def _shadowed(cache: Cache, b: int, clen: int,
              extra_slot: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """bool [B, S]: main-cache slots whose authoritative value is pending
    in the ring (must be excluded from the base attention mask) —
    ``core.ring.shadow_mask`` on this overlay's (validity, slot) view.
    ``extra_slot`` [B] adds one per-sequence slot (sentinel ``clen`` =
    none), e.g. the entry being staged this step."""
    return R.shadow_mask(ring_validity(cache), cache["ring_slot"], clen,
                         extra_rows=extra_slot)


def overlay_step(
    cache: Cache,
    vmask: jnp.ndarray,        # bool [B, S] main-cache validity after write
    slots: jnp.ndarray,        # int32 [B] this step's destination slots
    unload_mask: jnp.ndarray,  # bool [B] True = stage, False = direct
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-step overlay bookkeeping for ``decode_step``.

    Returns (full_mask [B, S+R] attention validity over cache ∪ ring,
    direct_slots [B] main-cache rows for the direct subset (sentinel = S
    drops staged sequences), cur — the ring column this step appends to).

    The authoritative value for a staged entry lives in the RING until
    drained, so its main-cache slot is shadowed out of the base mask.
    """
    b, clen = vmask.shape
    r = cache["ring_slot"].shape[1]
    cur = cache["ring_fill"]
    # this step's entry (appended at column cur) is valid where unloaded
    ring_valid = ring_validity(cache) | (
        (jnp.arange(r)[None, :] == cur) & unload_mask[:, None]
    )
    slot_now = jnp.where(unload_mask, slots, clen)
    shadowed = _shadowed(cache, b, clen, extra_slot=slot_now)
    full_mask = jnp.concatenate([vmask & ~shadowed, ring_valid], axis=1)
    direct_slots = jnp.where(unload_mask, clen, slots)
    return full_mask, direct_slots, cur


def drain_ring(cache: Cache, use_kernel: bool = True) -> Cache:
    """Bulk-copy all staged entries to their main-cache slots, empty ring.

    The copy is the unified drain primitive ``core.ring.scatter_rows``
    (-> ``staged_scatter`` Pallas kernel on TPU, jnp oracle elsewhere):
    per (layer, batch), ring rows [R, H*Dh] land at rows ``ring_slot[b]``
    of the cache's [S, H*Dh] view.
    """
    l, b, r, h, dh = cache["ring_k"].shape
    s = cache["k"].shape[2]
    valid = ring_validity(cache)

    def drain_one(dest, staging, slots, ok):
        # dest [S, H, Dh]; staging [R, H, Dh]
        out = R.scatter_rows(
            dest.reshape(s, h * dh), staging.reshape(r, h * dh), slots, ok,
            use_kernel=use_kernel,
        )
        return out.reshape(s, h, dh)

    def drain_layer(dest_l, staging_l):
        return jax.vmap(drain_one, in_axes=(0, 0, 0, 0))(
            dest_l, staging_l, cache["ring_slot"], valid
        )

    new_k = jax.vmap(drain_layer)(cache["k"], cache["ring_k"])
    new_v = jax.vmap(drain_layer)(cache["v"], cache["ring_v"])
    return dict(
        cache,
        k=new_k,
        v=new_v,
        ring_slot=jnp.full_like(cache["ring_slot"], -1),
        ring_fill=jnp.zeros_like(cache["ring_fill"]),  # dense mode: rewind
    )


def maybe_drain(
    cache: Cache,
    use_kernel: bool = False,
    incoming_slots: Optional[jnp.ndarray] = None,
) -> Tuple[Cache, jnp.ndarray]:
    """Fixed-shape conditional drain (serve-loop safe).

    Drains when the ring is full OR (when ``incoming_slots`` is given) when
    the NEXT step's destinations conflict with pending entries — the
    conflict-forced drain that keeps drain batches unique-destination.
    Returns (cache, drained bool) so jitted loops can count drains on
    device.
    """
    due = ring_full(cache)
    if incoming_slots is not None:
        due = due | ring_conflicts(cache, incoming_slots)
    cache = lax.cond(
        due,
        lambda c: drain_ring(c, use_kernel=use_kernel),
        lambda c: dict(c),
        cache,
    )
    return cache, due


def overlay_masks(cache: Cache, base_mask: jnp.ndarray) -> jnp.ndarray:
    """Validity mask for attention over [cache ∪ ring].

    base_mask: bool [B, S] for the main cache. Staged entries are valid up
    to ring_fill; their main-cache slots must be EXCLUDED from the base mask
    (the authoritative value lives in the ring until drained).
    """
    b, s = base_mask.shape
    shadowed = _shadowed(cache, b, s)
    return jnp.concatenate([base_mask & ~shadowed, ring_validity(cache)],
                           axis=1)


def overlay_kv(cache: Cache, layer_k: jnp.ndarray, layer_v: jnp.ndarray,
               ring_k: jnp.ndarray, ring_v: jnp.ndarray):
    """Concatenate main-cache and ring KV along the sequence axis."""
    return (
        jnp.concatenate([layer_k, ring_k], axis=1),
        jnp.concatenate([layer_v, ring_v], axis=1),
    )
