"""JAX version compatibility shims (single place for API drift).

The repo targets the jax_pallas container image (jax 0.4.37) but is written
against the current public API surface. Everything that drifted between
0.4.x and 0.5+/0.6+ is funneled through this module so call sites stay
clean and a version bump touches one file:

* ``AbstractMesh`` — 0.4.37 takes one ``((name, size), ...)`` shape tuple;
  newer releases take ``(axis_sizes, axis_names)``. Use
  :func:`make_abstract_mesh`.
* ``jax.sharding.get_abstract_mesh`` / ``use_abstract_mesh`` — public in
  newer releases; in 0.4.37 they live in ``jax._src.mesh`` as
  ``get_abstract_mesh`` / ``set_abstract_mesh`` (and ``get`` returns an
  empty *tuple*, not an empty mesh, when unset). :func:`get_abstract_mesh`
  here returns the current AbstractMesh or ``None``.
* ``Compiled.cost_analysis()`` — newer jax returns one dict; 0.4.37 returns
  a per-device *list* of dicts. :func:`cost_analysis_dict` always returns
  the dict.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Sequence

import jax
from jax.sharding import AbstractMesh


def make_abstract_mesh(axis_sizes: Sequence[int],
                       axis_names: Sequence[str]) -> AbstractMesh:
    """Version-agnostic ``AbstractMesh((16, 16), ("data", "model"))``."""
    try:  # jax >= 0.5-style (axis_sizes, axis_names)
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:  # 0.4.37: one ((name, size), ...) tuple
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def get_abstract_mesh() -> Optional[AbstractMesh]:
    """Current abstract-mesh context, or ``None`` when not under a mesh.

    Normalizes the 0.4.37 quirks: the getter lives in ``jax._src.mesh`` and
    yields ``()`` when no context is active; newer jax yields an *empty*
    AbstractMesh. Callers get ``None`` in both no-mesh cases.
    """
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is None:
        from jax._src import mesh as _mesh_lib

        getter = _mesh_lib.get_abstract_mesh
    mesh = getter()
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return None
    return mesh


@contextlib.contextmanager
def use_abstract_mesh(mesh: AbstractMesh):
    """Enter an abstract-mesh context (newer ``jax.sharding.use_abstract_mesh``
    or 0.4.37's ``jax._src.mesh.set_abstract_mesh``)."""
    enter = getattr(jax.sharding, "use_abstract_mesh", None)
    if enter is None:
        from jax._src import mesh as _mesh_lib

        enter = _mesh_lib.set_abstract_mesh
    with enter(mesh):
        yield


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` as one flat dict on every jax version."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca
