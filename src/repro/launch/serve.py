"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Batched prefill + decode with uRDMA KV-write routing (direct / staged /
adaptive). Reduced configs on CPU; production shardings under a mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models import build_model, media_spec, needs_media
from ..serve import ServeConfig, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--write-mode", default="adaptive",
                    choices=("direct", "staged", "adaptive"))
    ap.add_argument("--ring-size", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), args.max_seq)
    prompt = jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )
    media = None
    if needs_media(cfg):
        media = jax.random.normal(
            jax.random.key(2), media_spec(cfg, args.batch, jnp.float32).shape
        )

    eng = ServeEngine(model, params, ServeConfig(
        max_seq=args.max_seq, write_mode=args.write_mode,
        ring_size=args.ring_size,
    ))
    t0 = time.perf_counter()
    toks = eng.generate(prompt, args.gen_len, media=media)
    dt = time.perf_counter() - t0
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen_len / dt:.1f} tok/s)")
    print(f"write-path stats: {eng.stats}")


if __name__ == "__main__":
    main()
