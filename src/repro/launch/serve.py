"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

One front door (``repro.serve.Engine``), two workload shapes:

* default — serve ``--batch`` same-length prompts concurrently (one slot
  per prompt) and report throughput: the batched-generate workload.
* ``--batched`` — continuous batching: a stream of ``--requests``
  synthetic requests admitted FIFO into ``--slots`` serving slots,
  decoded in jitted scan segments with EOS/max-len retirement between
  them (optionally ``--chunked`` mixed-phase prefill).

The write path and routing policy are registry names
(``repro.core.paths`` / ``repro.core.policy``); sampling is per-request
``SamplingParams``. Reduced configs on CPU; production shardings under a
mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..data import synthetic_requests
from ..models import media_spec, needs_media
from ..models.sampling import SamplingParams
from ..serve import Engine, EngineConfig, build_model_and_params
from ..serve.scheduler import paged_capable


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--write-mode", "--path", dest="path", default="adaptive",
                    help="registered WritePath name (direct/staged/"
                         "adaptive/... — repro.core.paths)")
    ap.add_argument("--policy", default=None,
                    help="registered RoutingPolicy name (default: the "
                         "path's default policy)")
    ap.add_argument("--temperature", type=float, default=None,
                    help="sampling temperature (default: greedy)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=None,
                    help="per-request sampling seed")
    ap.add_argument("--ring-size", type=int, default=8)
    ap.add_argument("--batched", action="store_true",
                    help="continuous batching over the paged KV pool")
    ap.add_argument("--requests", type=int, default=16,
                    help="(--batched) synthetic request count")
    ap.add_argument("--slots", type=int, default=8,
                    help="(--batched) serving slots")
    ap.add_argument("--segment-len", type=int, default=16,
                    help="(--batched) decode steps per scan segment")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--chunked", action="store_true",
                    help="(--batched) admit immediately, prefill prompts "
                         "in chunks inside the decode scan (DESIGN.md §5)")
    ap.add_argument("--chunk-size", type=int, default=16,
                    help="(--chunked) prompt tokens per prefill chunk")
    ap.add_argument("--long-prompt-len", type=int, default=0,
                    help="(--batched) if > 0, every 4th request carries a "
                         "prompt of this length (mixed workload)")
    args = ap.parse_args()

    cfg, model, params = build_model_and_params(args.arch, args.max_seq)

    path = args.path
    if path != "direct" and not paged_capable(model):
        print(f"[serve] {cfg.name}: lanes layout is direct-only; "
              f"downgrading --write-mode {path} -> direct")
        path = "direct"
    sp = SamplingParams(
        temperature=args.temperature, top_k=args.top_k, top_p=args.top_p,
        seed=args.seed, max_tokens=args.gen_len,
    )

    if args.batched:
        media_shape = None
        if needs_media(cfg):
            media_shape = media_spec(cfg, 1, jnp.float32).shape[1:]
        plens = args.prompt_len
        if args.long_prompt_len:
            plens = [args.long_prompt_len] + [args.prompt_len] * 3
        queue = synthetic_requests(
            args.requests, plens, cfg.vocab, args.gen_len,
            media_shape=media_shape, params=sp,
        )
        eng = Engine.from_config(EngineConfig(
            max_seq=args.max_seq, n_slots=args.slots,
            segment_len=args.segment_len, path=path, policy=args.policy,
            page_size=args.page_size, ring_size=args.ring_size,
            chunked=args.chunked, chunk_size=args.chunk_size,
        ), model, params)
        t0 = time.perf_counter()
        outputs = eng.serve(queue)
        dt = time.perf_counter() - t0
        n_toks = sum(len(t) for t in outputs.values())
        mode = f"{eng.layout}, chunked" if args.chunked else eng.layout
        print(f"[{mode}] served {len(outputs)} requests / {n_toks} "
              f"tokens in {dt:.2f}s ({n_toks / dt:.1f} tok/s)")
        if eng.ttft:
            ms = sorted(v * 1e3 for v in eng.ttft.values())
            print(f"ttft: mean {sum(ms) / len(ms):.1f} ms, "
                  f"max {ms[-1]:.1f} ms")
        print(f"write-path stats: {eng.stats}")
        return

    rng = np.random.RandomState(1)
    prompts = [rng.randint(0, cfg.vocab, size=args.prompt_len)
               for _ in range(args.batch)]
    media = None
    if needs_media(cfg):
        media = [np.asarray(jax.random.normal(
            jax.random.key(2), media_spec(cfg, 1, jnp.float32).shape[1:]))
            for _ in range(args.batch)]

    eng = Engine.from_config(EngineConfig(
        max_seq=args.max_seq, n_slots=args.batch, path=path,
        policy=args.policy, ring_size=args.ring_size,
        page_size=args.page_size,
    ), model, params)
    t0 = time.perf_counter()
    comps = eng.generate(prompts, sp, media=media)
    dt = time.perf_counter() - t0
    n_toks = sum(c.n_tokens for c in comps)
    print(f"generated {len(comps)} x {args.gen_len} tokens in {dt:.2f}s "
          f"({n_toks / dt:.1f} tok/s)")
    print(f"write-path stats: {eng.stats}")


if __name__ == "__main__":
    main()
