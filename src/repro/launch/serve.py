"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Two serving modes:

* default — batched prefill + device-resident decode with uRDMA KV-write
  routing (direct / staged / adaptive) through ``ServeEngine``.
* ``--batched`` — slot-based continuous batching over the paged KV pool
  (``BatchedServeEngine``): a stream of ``--requests`` synthetic requests
  is admitted FIFO into ``--slots`` serving slots, decoded in jitted scan
  segments with EOS/max-len retirement between them.

Reduced configs on CPU; production shardings under a mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..data import synthetic_requests
from ..models import build_model, media_spec, needs_media
from ..serve import BatchConfig, BatchedServeEngine, ServeConfig, ServeEngine
from ..serve.scheduler import paged_capable


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--write-mode", default="adaptive",
                    choices=("direct", "staged", "adaptive"))
    ap.add_argument("--ring-size", type=int, default=8)
    ap.add_argument("--batched", action="store_true",
                    help="continuous batching over the paged KV pool")
    ap.add_argument("--requests", type=int, default=16,
                    help="(--batched) synthetic request count")
    ap.add_argument("--slots", type=int, default=8,
                    help="(--batched) serving slots")
    ap.add_argument("--segment-len", type=int, default=16,
                    help="(--batched) decode steps per scan segment")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--chunked", action="store_true",
                    help="(--batched) admit immediately, prefill prompts "
                         "in chunks inside the decode scan (DESIGN.md §5)")
    ap.add_argument("--chunk-size", type=int, default=16,
                    help="(--chunked) prompt tokens per prefill chunk")
    ap.add_argument("--long-prompt-len", type=int, default=0,
                    help="(--batched) if > 0, every 4th request carries a "
                         "prompt of this length (mixed workload)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), args.max_seq)

    if args.batched:
        media_shape = None
        if needs_media(cfg):
            media_shape = media_spec(cfg, 1, jnp.float32).shape[1:]
        plens = args.prompt_len
        if args.long_prompt_len:
            plens = [args.long_prompt_len] + [args.prompt_len] * 3
        queue = synthetic_requests(
            args.requests, plens, cfg.vocab, args.gen_len,
            media_shape=media_shape,
        )
        write_mode = args.write_mode
        if write_mode != "direct" and not paged_capable(model):
            print(f"[serve] {cfg.name}: lanes layout is direct-only; "
                  f"downgrading --write-mode {write_mode} -> direct")
            write_mode = "direct"
        eng = BatchedServeEngine(model, params, BatchConfig(
            max_seq=args.max_seq, n_slots=args.slots,
            segment_len=args.segment_len, write_mode=write_mode,
            page_size=args.page_size, ring_size=args.ring_size,
            chunked=args.chunked, chunk_size=args.chunk_size,
        ))
        t0 = time.perf_counter()
        outputs = eng.serve(queue)
        dt = time.perf_counter() - t0
        n_toks = sum(len(t) for t in outputs.values())
        mode = f"{eng.layout}, chunked" if args.chunked else eng.layout
        print(f"[{mode}] served {len(outputs)} requests / {n_toks} "
              f"tokens in {dt:.2f}s ({n_toks / dt:.1f} tok/s)")
        if eng.ttft:
            ms = sorted(v * 1e3 for v in eng.ttft.values())
            print(f"ttft: mean {sum(ms) / len(ms):.1f} ms, "
                  f"max {ms[-1]:.1f} ms")
        print(f"write-path stats: {eng.stats}")
        return

    prompt = jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )
    media = None
    if needs_media(cfg):
        media = jax.random.normal(
            jax.random.key(2), media_spec(cfg, args.batch, jnp.float32).shape
        )

    eng = ServeEngine(model, params, ServeConfig(
        max_seq=args.max_seq, write_mode=args.write_mode,
        ring_size=args.ring_size,
    ))
    t0 = time.perf_counter()
    toks = eng.generate(prompt, args.gen_len, media=media)
    dt = time.perf_counter() - t0
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen_len / dt:.1f} tok/s)")
    print(f"write-path stats: {eng.stats}")


if __name__ == "__main__":
    main()
