"""Dry-run cell builders: (arch x shape) -> (step_fn, sharded abstract args).

Shared by launch/dryrun.py (full-depth compile: memory + compilability) and
launch/roofline.py (depth-reduced unrolled probes: exact FLOP/byte/collective
accounting). Nothing here allocates device memory — all inputs are
ShapeDtypeStructs with NamedShardings attached.

Step kinds:
  train    -> make_train_step (grad-accum microbatches, remat, AdamW)
  prefill  -> chunk_prefill of the LAST chunk (worst case: queries attend
              the full 32k cache). Chunked prefill is the production path
              at 32k — one-shot prefill would materialize O(S^2) scores.
  decode   -> decode_step (one new token against a seq_len KV cache)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from ..distributed import input_shardings, state_shardings, with_shardings
from ..models import build_model, input_specs, media_spec, needs_media
from ..optim import AdamW, warmup_cosine
from ..train import init_train_state, make_train_step

# per-arch microbatch count for the train_4k cell (global batch 256):
# bounds activation/dispatch memory; tuned from memory_analysis.
TRAIN_MICROBATCHES = {
    "default": 8,
    "qwen2-7b": 16,
    "granite-moe-3b-a800m": 16,
    "qwen3-moe-235b-a22b": 16,
    "llama-3.2-vision-90b": 16,
    "nemotron-4-15b": 16,
    "whisper-medium": 16,
    "zamba2-2.7b": 16,
}

# chunk size for the prefill cells (memory/agility trade; tuned per arch —
# qwen2's headdim-TP keeps full head count on each shard, so smaller chunks)
PREFILL_CHUNK = {
    "default": 1024,
    "qwen2-7b": 512,
}


def _n_hot(cfg: ModelConfig) -> int:
    return max(1, cfg.n_experts // 4) if cfg.n_experts else 0


def make_optimizer(total_steps: int = 10_000) -> AdamW:
    return AdamW(lr=warmup_cosine(3e-4, 200, total_steps))


def abstract_train_state(cfg: ModelConfig, model, opt: AdamW, max_seq: int):
    return jax.eval_shape(
        lambda k: init_train_state(model, opt, k, max_seq, n_hot_experts=_n_hot(cfg)),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )


def build_cell(
    cfg: ModelConfig,
    shape: ShapeSpec,
    mesh,
    *,
    unroll: bool = False,
    microbatches: Optional[int] = None,
    dispatch_mode: str = "staged",
) -> Tuple[Any, Tuple, Dict]:
    """-> (step_fn, args (sharded ShapeDtypeStructs), meta)."""
    kwargs = {"unroll": unroll}
    if cfg.n_experts:
        kwargs["dispatch_mode"] = dispatch_mode
    model = build_model(cfg, **kwargs)
    specs = input_specs(cfg, shape)
    meta: Dict[str, Any] = {"arch": cfg.name, "shape": shape.name, "step": shape.step}

    if shape.step == "train":
        mb = microbatches or TRAIN_MICROBATCHES.get(
            cfg.name, TRAIN_MICROBATCHES["default"]
        )
        meta["microbatches"] = mb
        opt = make_optimizer()
        step = make_train_step(
            model, opt, microbatches=mb, remat=True, n_hot_experts=_n_hot(cfg),
            unroll_accum=unroll,
        )
        a_state = abstract_train_state(cfg, model, opt, shape.seq_len)
        s_state = with_shardings(a_state, state_shardings(cfg, mesh, a_state))
        s_batch = input_shardings(cfg, mesh, specs, "train")
        return step, (s_state, s_batch), meta

    # serving cells share the param shardings of training (FSDP+TP)
    from ..distributed import param_shardings

    a_params = jax.eval_shape(
        lambda k: model.init(k, shape.seq_len), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    s_params = with_shardings(a_params, param_shardings(cfg, mesh, a_params))

    if shape.step == "prefill":
        chunk = PREFILL_CHUNK.get(cfg.name, PREFILL_CHUNK["default"])
        chunk = min(chunk, shape.seq_len)
        meta["chunk"] = chunk
        start = shape.seq_len - chunk  # last chunk = worst case
        b = shape.global_batch
        cache = jax.eval_shape(
            lambda: model.init_cache(b, shape.seq_len, jnp.dtype(cfg.dtype))
        )
        tok = jax.ShapeDtypeStruct((b, chunk), jnp.int32)
        args = {"cache": cache, "tokens": tok}
        if needs_media(cfg):
            args["media"] = media_spec(cfg, b, jnp.dtype(cfg.dtype))
        s_args = input_shardings(cfg, mesh, args, "prefill")

        def step(params, cache, tokens, media=None):
            return model.chunk_prefill(params, cache, tokens, start, media=media)

        return step, (s_params, s_args["cache"], s_args["tokens"],
                      s_args.get("media")), meta

    if shape.step == "decode":
        s_args = input_shardings(cfg, mesh, specs, "decode")

        def step(params, cache, tokens, pos):
            return model.decode_step(params, cache, tokens, pos)

        return step, (s_params, s_args["cache"], s_args["tokens"], s_args["pos"]), meta

    raise ValueError(shape.step)


def depth_probes(cfg: ModelConfig) -> list:
    """Depth knobs for the affine roofline probes (see launch/roofline.py).

    Returns a list of (label, replace_kwargs, depth_value) — cost is affine
    in each depth knob; two probes give base + marginal.
    """
    if cfg.family == "vlm":
        g = cfg.cross_attn_every
        return [("d", {"n_layers": g}, 1), ("d", {"n_layers": 2 * g}, 2)]
    if cfg.family == "hybrid":
        g = cfg.hybrid_attn_every
        return [("d", {"n_layers": g}, 1), ("d", {"n_layers": 2 * g}, 2)]
    if cfg.family == "encdec":
        return [
            ("d", {"n_layers": 1, "n_enc_layers": 1}, (1, 1)),
            ("d", {"n_layers": 2, "n_enc_layers": 1}, (2, 1)),
            ("enc", {"n_layers": 1, "n_enc_layers": 2}, (1, 2)),
        ]
    return [("d", {"n_layers": 1}, 1), ("d", {"n_layers": 2}, 2)]


def probe_config(cfg: ModelConfig, replace_kwargs: dict) -> ModelConfig:
    return dataclasses.replace(cfg, **replace_kwargs)


def full_depth_units(cfg: ModelConfig):
    """How many 'depth units' the full config has, matching depth_probes."""
    if cfg.family == "vlm":
        return cfg.n_layers // cfg.cross_attn_every
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.hybrid_attn_every
    if cfg.family == "encdec":
        return (cfg.n_layers, cfg.n_enc_layers)
    return cfg.n_layers
