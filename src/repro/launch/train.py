"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On real hardware this runs under multi-controller JAX (one process per
host; jax.distributed.initialize() from the scheduler environment); in this
container it runs single-process on CPU with the reduced config by default.
The full production path (mesh, shardings, microbatching, checkpoints,
fault tolerance) is identical either way — only device count differs.
"""
from __future__ import annotations

import argparse
import logging

import jax

from ..configs import get_config
from ..data import DataConfig, Pipeline, SyntheticSource
from ..distributed import state_shardings
from ..models import build_model
from ..optim import AdamW, warmup_cosine
from ..train import Trainer, TrainerConfig, init_train_state, make_train_step
from .mesh import make_production_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="CPU-scale config (full configs need TPUs)")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--dispatch-mode", default="staged",
                    choices=("direct", "staged", "adaptive"))
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(message)s")
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    kwargs = {"dispatch_mode": args.dispatch_mode} if cfg.n_experts else {}
    model = build_model(cfg, **kwargs)
    n_hot = max(1, cfg.n_experts // 4) if cfg.n_experts else 0

    opt = AdamW(lr=warmup_cosine(args.lr, max(args.steps // 20, 1), args.steps))
    state = init_train_state(model, opt, jax.random.key(0), args.seq, n_hot)
    step_fn = make_train_step(model, opt, microbatches=args.microbatches,
                              n_hot_experts=n_hot)

    if len(jax.devices()) > 1:
        mesh = make_production_mesh()
        a_state = jax.eval_shape(lambda s: s, state)
        sh = state_shardings(cfg, mesh, a_state)
        state = jax.tree.map(jax.device_put, state, sh)
        with mesh:
            step = jax.jit(step_fn, donate_argnums=0)
    else:
        step = jax.jit(step_fn, donate_argnums=0)

    dc = DataConfig(seq_len=args.seq, global_batch=args.batch, vocab=cfg.vocab,
                    num_hosts=jax.process_count(), host_index=jax.process_index())
    pipe = Pipeline(SyntheticSource(dc)).start()
    trainer = Trainer(step, state, pipe, TrainerConfig(
        total_steps=args.steps,
        checkpoint_every=max(args.steps // 4, 1),
        checkpoint_dir=args.checkpoint_dir,
    ))
    trainer.maybe_resume()
    result = trainer.run()
    pipe.stop()
    print(f"done: {result}")


if __name__ == "__main__":
    main()
