"""Render EXPERIMENTS.md tables from the dry-run JSON records.

    PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
import os

from ..configs import ALL_SHAPES, ARCHS


def load(dirname):
    recs = {}
    for fname in sorted(os.listdir(dirname)):
        if fname.endswith(".json"):
            with open(os.path.join(dirname, fname)) as f:
                r = json.load(f)
            recs[(r["arch"], r["shape"])] = r
    return recs


def fmt_ms(s):
    return f"{s*1e3:.2f}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load(args.dir)

    print("### §Dry-run: compile + memory per (arch x shape), both meshes\n")
    print("| arch | shape | status | mem/dev 1-pod (GB) | mem/dev 2-pod (GB) "
          "| compile 1-pod (s) | compile 2-pod (s) |")
    print("|---|---|---|---|---|---|---|")
    for arch in ARCHS:
        for shape in ALL_SHAPES:
            r = recs.get((arch, shape.name))
            if r is None:
                print(f"| {arch} | {shape.name} | MISSING | | | | |")
                continue
            if r["status"] == "skipped":
                print(f"| {arch} | {shape.name} | skipped* | | | | |")
                continue
            if r["status"] == "error":
                print(f"| {arch} | {shape.name} | ERROR | | | | |")
                continue
            sp, mp = r["single_pod"], r["multi_pod"]
            print(f"| {arch} | {shape.name} | ok | {sp['per_device_gb']} | "
                  f"{mp['per_device_gb']} | {sp['compile_s']} | {mp['compile_s']} |")

    print("\n### §Roofline: per-device terms (single-pod 16x16, 256 chips)\n")
    print("| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
          "dominant | model-FLOPs ratio | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in ARCHS:
        for shape in ALL_SHAPES:
            r = recs.get((arch, shape.name))
            if not r or r["status"] != "ok" or "roofline" not in r:
                continue
            t = r["roofline"]
            print(f"| {arch} | {shape.name} | {fmt_ms(t['compute_s'])} | "
                  f"{fmt_ms(t['memory_s'])} | {fmt_ms(t['collective_s'])} | "
                  f"{t['dominant']} | {t['model_flops_ratio']:.2f} | "
                  f"{t['roofline_fraction']:.3f} |")

    n_ok = sum(1 for r in recs.values() if r["status"] == "ok")
    n_skip = sum(1 for r in recs.values() if r["status"] == "skipped")
    n_err = sum(1 for r in recs.values() if r["status"] == "error")
    print(f"\ncells: {n_ok} ok / {n_skip} skipped (documented) / {n_err} error")


if __name__ == "__main__":
    main()
