import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

For every (architecture x input shape) cell this lowers + compiles the full
production program on BOTH meshes:

    single-pod:  (16, 16)      = ("data", "model")        256 chips
    multi-pod:   (2, 16, 16)   = ("pod", "data", "model") 512 chips

and records ``memory_analysis()`` (proof of HBM fit) and
``cost_analysis()`` + parsed collective bytes (for §Roofline). The full
compile runs the SCANNED stacks (O(1) HLO in depth); exact FLOP/byte totals
come from the roofline prober (launch/roofline.py) on the single-pod mesh.

Usage:
    python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    python -m repro.launch.dryrun --all [--out experiments/dryrun]
    python -m repro.launch.dryrun --all --skip-probes   # compile-only pass
"""
import argparse
import json
import time
import traceback

import jax

from ..compat import cost_analysis_dict, use_abstract_mesh
from ..configs import ALL_SHAPES, ARCHS, get_config, get_shape, shape_applicable
from . import cells as C
from . import roofline as R
from .mesh import make_production_mesh


def memory_dict(ma) -> dict:
    return {
        k: int(getattr(ma, k))
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "alias_size_in_bytes",
        )
    }


def run_cell(arch: str, shape_name: str, *, probes: bool = True,
             dispatch_mode: str = "staged") -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped", "reason": why}

    rec: dict = {"arch": arch, "shape": shape_name, "status": "ok",
                 "dispatch_mode": dispatch_mode if cfg.n_experts else None}
    for mesh_kind, multi_pod in (("single_pod", False), ("multi_pod", True)):
        mesh = make_production_mesh(multi_pod=multi_pod)
        t0 = time.time()
        step, args, meta = C.build_cell(cfg, shape, mesh,
                                        dispatch_mode=dispatch_mode)
        args = tuple(a for a in args if a is not None)
        with mesh, use_abstract_mesh(mesh.abstract_mesh):
            lowered = jax.jit(step).lower(*args)
            compiled = lowered.compile()
        ma = compiled.memory_analysis()
        rec[mesh_kind] = {
            "compile_s": round(time.time() - t0, 1),
            "memory": memory_dict(ma),
            "per_device_gb": round(
                (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 3),
            **meta,
        }
        # raw (scan-body-once) cost numbers for reference; exact totals come
        # from the probes below
        rec[mesh_kind]["cost_raw"] = {
            k: float(v) for k, v in cost_analysis_dict(compiled).items()
            if k in ("flops", "bytes accessed")
        }
        rec[mesh_kind]["collectives_raw"] = R.collective_bytes(compiled.as_text())

    if probes:
        mesh = make_production_mesh(multi_pod=False)
        t0 = time.time()
        metrics = R.probe_cell(cfg, shape, mesh, dispatch_mode=dispatch_mode)
        rec["probe_s"] = round(time.time() - t0, 1)
        rec["metrics"] = metrics
        rec["roofline"] = R.roofline_terms(metrics, cfg, shape)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-probes", action="store_true")
    ap.add_argument("--dispatch-mode", default="staged",
                    choices=("direct", "staged", "adaptive"))
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true",
                    help="resume a sweep: skip cells with an ok/skipped JSON")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in ALL_SHAPES:
                cells.append((arch, shape.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        tag = f"{arch}__{shape}"
        path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            with open(path) as f:
                old = json.load(f)
            if old.get("status") in ("ok", "skipped"):
                print(f"[cached ] {tag}", flush=True)
                continue
        try:
            rec = run_cell(arch, shape, probes=not args.skip_probes,
                           dispatch_mode=args.dispatch_mode)
        except Exception as e:  # noqa: BLE001 — record and continue the sweep
            rec = {"arch": arch, "shape": shape, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc(limit=8)}
            failures += 1
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        status = rec["status"]
        extra = ""
        if status == "ok":
            extra = (f" mem/dev {rec['single_pod']['per_device_gb']}GB"
                     f" compile {rec['single_pod']['compile_s']}s"
                     f"+{rec['multi_pod']['compile_s']}s")
            if "roofline" in rec:
                r = rec["roofline"]
                extra += (f" | compute {r['compute_s']*1e3:.2f}ms"
                          f" mem {r['memory_s']*1e3:.2f}ms"
                          f" coll {r['collective_s']*1e3:.2f}ms"
                          f" -> {r['dominant']}")
        elif status == "skipped":
            extra = " " + rec["reason"][:60]
        else:
            extra = " " + rec["error"][:90]
        print(f"[{status:7s}] {tag}{extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
