"""Production meshes.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
device query, and tests/benches must keep seeing 1 CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod stacks 2 pods -> 512 chips.

    Axis roles: "pod" = cross-pod data parallelism (gradient all-reduce над
    the DCN/ICI boundary), "data" = FSDP + batch DP, "model" = TP/EP.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU tests/examples (axis names preserved)."""
    return jax.make_mesh((1, 1), ("data", "model"))
