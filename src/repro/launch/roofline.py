"""Roofline analysis from compiled dry-run artifacts.

Hardware model (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI. Per (arch x shape) on the single-pod mesh we derive:

    compute_s    = FLOPs_per_device / 197e12
    memory_s     = bytes_per_device / 819e9
    collective_s = collective_bytes_per_device / 50e9

``compiled.cost_analysis()`` is PER-DEVICE on an SPMD module (verified: a
512-way-sharded einsum reports global/512 flops), so no further division by
chip count is needed. Collective bytes are parsed from ``compiled.as_text()``
(post-partitioning, i.e. per-device shapes): for each all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute op we count
the LARGEST shape on the op line (≈ bytes crossing the local ICI links; an
all-reduce moves ~2x this in a ring — reported as-is and noted in
EXPERIMENTS.md).

Scan-body accounting: XLA's cost analysis counts a while-loop body ONCE, so
all probe lowers run with UNROLLED stacks on depth-reduced configs, and the
full-depth cost is reconstructed affinely:

    cost(L) = base + marginal * L        (marginal from depth-1/depth-2)
    train:  cost(L, M) = opt(L) + M * micro(L); opt scaled by param ratio.

The full-depth scanned compile (launch/dryrun.py) independently proves
compilability and memory fit.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict

import jax

from ..compat import cost_analysis_dict, use_abstract_mesh
from ..configs.base import ModelConfig, ShapeSpec
from . import cells as C

PEAK_FLOPS = 197e12   # bf16 / chip
HBM_BW = 819e9        # B/s
ICI_BW = 50e9         # B/s/link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*[^=]*?\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\("
)
_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|"
                       r"u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-kind byte totals for collective ops (per device, post-SPMD)."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done(" in line:
            continue
        kind = m.group(1)
        sizes = [_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(line)]
        if sizes:
            out[kind] = out.get(kind, 0.0) + max(sizes)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def compiled_metrics(compiled) -> Dict[str, float]:
    ca = cost_analysis_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": coll["total"],
        **{f"coll_{k.replace('-', '_')}": v for k, v in coll.items() if k != "total"},
    }


def _combine(a: Dict[str, float], b: Dict[str, float], fa: float, fb: float):
    keys = set(a) | set(b)
    return {k: fa * a.get(k, 0.0) + fb * b.get(k, 0.0) for k in keys}


# ---------------------------------------------------------------------------
# Probing
# ---------------------------------------------------------------------------


def _lower_metrics(cfg, shape, mesh, *, microbatches=None, dispatch_mode="staged"):
    step, args, _meta = C.build_cell(
        cfg, shape, mesh, unroll=True, microbatches=microbatches,
        dispatch_mode=dispatch_mode,
    )
    args = tuple(a for a in args if a is not None)
    with mesh, use_abstract_mesh(mesh.abstract_mesh):
        compiled = jax.jit(step).lower(*args).compile()
    return compiled_metrics(compiled)


def probe_cell(
    cfg: ModelConfig,
    shape: ShapeSpec,
    mesh,
    dispatch_mode: str = "staged",
) -> Dict[str, float]:
    """Affine-extrapolated per-device metrics for the FULL-depth cell."""
    probes = C.depth_probes(cfg)

    if shape.step == "train":
        mb = C.TRAIN_MICROBATCHES.get(cfg.name, C.TRAIN_MICROBATCHES["default"])
        micro_bs = shape.global_batch // mb
        micro_shape = dataclasses.replace(shape, global_batch=micro_bs)

        if cfg.family == "encdec":
            c11 = C.probe_config(cfg, probes[0][1])
            c21 = C.probe_config(cfg, probes[1][1])
            c12 = C.probe_config(cfg, probes[2][1])
            p11 = _lower_metrics(c11, micro_shape, mesh, microbatches=1,
                                 dispatch_mode=dispatch_mode)
            p21 = _lower_metrics(c21, micro_shape, mesh, microbatches=1,
                                 dispatch_mode=dispatch_mode)
            p12 = _lower_metrics(c12, micro_shape, mesh, microbatches=1,
                                 dispatch_mode=dispatch_mode)
            two_shape = dataclasses.replace(shape, global_batch=2 * micro_bs)
            pm2 = _lower_metrics(c11, two_shape, mesh, microbatches=2,
                                 dispatch_mode=dispatch_mode)
            micro_11 = _combine(pm2, p11, 1.0, -1.0)          # one extra microbatch
            opt_11 = _combine(p11, micro_11, 1.0, -1.0)
            mu_dec = _combine(p21, p11, 1.0, -1.0)
            mu_enc = _combine(p12, p11, 1.0, -1.0)
            ld, le = cfg.n_layers, cfg.n_enc_layers
            micro_l = _combine(
                _combine(micro_11, mu_dec, 1.0, float(ld - 1)),
                mu_enc, 1.0, float(le - 1),
            )
            ratio = cfg.param_count() / c11.param_count()
            opt_l = {k: v * ratio for k, v in opt_11.items()}
            return _combine(opt_l, micro_l, 1.0, float(mb))

        d1_cfg = C.probe_config(cfg, probes[0][1])
        d2_cfg = C.probe_config(cfg, probes[1][1])
        p11 = _lower_metrics(d1_cfg, micro_shape, mesh, microbatches=1,
                             dispatch_mode=dispatch_mode)
        p21 = _lower_metrics(d2_cfg, micro_shape, mesh, microbatches=1,
                             dispatch_mode=dispatch_mode)
        two_shape = dataclasses.replace(shape, global_batch=2 * micro_bs)
        p12 = _lower_metrics(d1_cfg, two_shape, mesh, microbatches=2,
                             dispatch_mode=dispatch_mode)
        micro_1 = _combine(p12, p11, 1.0, -1.0)   # cost of one more microbatch @d1
        opt_1 = _combine(p11, micro_1, 1.0, -1.0)
        mu = _combine(p21, p11, 1.0, -1.0)        # per-depth-unit marginal @M=1
        units = C.full_depth_units(cfg)
        micro_l = _combine(micro_1, mu, 1.0, float(units - 1))
        ratio = cfg.param_count() / d1_cfg.param_count()
        opt_l = {k: v * ratio for k, v in opt_1.items()}
        return _combine(opt_l, micro_l, 1.0, float(mb))

    # prefill / decode: cost(L) = p1 + (L-1) * (p2 - p1)
    if cfg.family == "encdec" and shape.step == "prefill":
        c11 = C.probe_config(cfg, probes[0][1])
        c21 = C.probe_config(cfg, probes[1][1])
        c12 = C.probe_config(cfg, probes[2][1])
        p11 = _lower_metrics(c11, shape, mesh, dispatch_mode=dispatch_mode)
        p21 = _lower_metrics(c21, shape, mesh, dispatch_mode=dispatch_mode)
        p12 = _lower_metrics(c12, shape, mesh, dispatch_mode=dispatch_mode)
        mu_dec = _combine(p21, p11, 1.0, -1.0)
        mu_enc = _combine(p12, p11, 1.0, -1.0)
        return _combine(
            _combine(p11, mu_dec, 1.0, float(cfg.n_layers - 1)),
            mu_enc, 1.0, float(cfg.n_enc_layers - 1),
        )

    d1_cfg = C.probe_config(cfg, probes[0][1])
    d2_cfg = C.probe_config(cfg, probes[1][1])
    p1 = _lower_metrics(d1_cfg, shape, mesh, dispatch_mode=dispatch_mode)
    p2 = _lower_metrics(d2_cfg, shape, mesh, dispatch_mode=dispatch_mode)
    units = C.full_depth_units(cfg)
    if isinstance(units, tuple):
        # enc-dec decode: the encoder does not run in decode_step — only
        # the decoder depth scales (probes 0/1 vary decoder layers).
        units = units[0]
    mu = _combine(p2, p1, 1.0, -1.0)
    return _combine(p1, mu, 1.0, float(units - 1))


# ---------------------------------------------------------------------------
# Terms
# ---------------------------------------------------------------------------


def roofline_terms(metrics: Dict[str, float], cfg: ModelConfig,
                   shape: ShapeSpec) -> Dict[str, Any]:
    compute_s = metrics["flops"] / PEAK_FLOPS
    memory_s = metrics["bytes"] / HBM_BW
    coll_s = metrics["coll_bytes"] / ICI_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", coll_s),
        key=lambda kv: kv[1],
    )[0]
    # MODEL_FLOPS: 6·N·D (dense) / 6·N_active·D (MoE); D = tokens processed.
    if shape.step == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * cfg.active_param_count() * tokens
    elif shape.step == "prefill":
        chunk = C.PREFILL_CHUNK.get(cfg.name, C.PREFILL_CHUNK["default"])
        tokens = shape.global_batch * min(chunk, shape.seq_len)
        model_flops = 2 * cfg.active_param_count() * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        model_flops = 2 * cfg.active_param_count() * tokens
    model_flops_per_dev = model_flops / 256  # single-pod mesh
    useful = model_flops_per_dev / metrics["flops"] if metrics["flops"] else 0.0
    bound = max(compute_s, memory_s, coll_s)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "model_flops_ratio": useful,
        "roofline_fraction": (compute_s / bound) if bound else 0.0,
        "flops_per_dev": metrics["flops"],
        "bytes_per_dev": metrics["bytes"],
        "coll_bytes_per_dev": metrics["coll_bytes"],
    }
