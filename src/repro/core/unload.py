"""Unload module (paper §3.1): staging ring buffer + drain.

The unload path replaces a write to an arbitrary destination region with

  1. an append into the next slots of a small, reused STAGING RING on the
     target (initiator side: slot allocation + metadata bookkeeping — the
     paper's "rerouting the writeImm to the next slot in the target's
     temporary buffer" and "updating the local metadata about buffer usage");
  2. a target-side DRAIN that (a) validates each staged entry against uMTT
     (address/size/stag/permission — security parity) and (b) copies the
     payload to its true destination (functional parity).

Entries carry (region, offset, size, stag) alongside the payload — the
paper packs the destination address into the writeImm payload and the stag
into the immediate value; we keep them as separate arrays of one staging
record.

Everything is fixed-shape and jit-compatible; the ring state is a pytree
carried through training/serving steps.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import umtt as U


class StagingRing(NamedTuple):
    """Target-side staging buffer (one per queue pair in the paper)."""

    payload: jnp.ndarray  # [cap, width] staged payloads
    region: jnp.ndarray   # int32[cap] destination region id
    offset: jnp.ndarray   # int32[cap] element offset within the region
    size: jnp.ndarray     # int32[cap] valid payload elements
    stag: jnp.ndarray     # int32[cap] steering tag for the uMTT check
    live: jnp.ndarray     # bool[cap] slot holds an undrained entry
    head: jnp.ndarray     # int32 scalar — next slot to write (append cursor)


def make_ring(capacity: int, width: int, dtype=jnp.float32) -> StagingRing:
    return StagingRing(
        payload=jnp.zeros((capacity, width), dtype),
        region=jnp.zeros((capacity,), jnp.int32),
        offset=jnp.zeros((capacity,), jnp.int32),
        size=jnp.zeros((capacity,), jnp.int32),
        stag=jnp.zeros((capacity,), jnp.int32),
        live=jnp.zeros((capacity,), jnp.bool_),
        head=jnp.zeros((), jnp.int32),
    )


def append(
    ring: StagingRing,
    payload: jnp.ndarray,  # [n, width]
    region: jnp.ndarray,
    offset: jnp.ndarray,
    size: jnp.ndarray,
    stag: jnp.ndarray,
    mask: jnp.ndarray,  # bool[n] — which requests take the unload path
) -> Tuple[StagingRing, jnp.ndarray]:
    """Sequential append of masked entries at the ring head.

    Staging writes are CONTIGUOUS by construction (slot = head + rank of
    the request among unloaded ones) — this is the whole point: the ring
    is small and sequentially written, hence "MTT-cache-resident" in the
    paper and dense/fusable on TPU.

    Returns (new ring, slot[n] — assigned slot per request, -1 if not
    staged). Entries beyond capacity wrap (callers drain before overflow;
    ``need_drain`` exposes the watermark).
    """
    cap = ring.payload.shape[0]
    rank = jnp.cumsum(mask.astype(jnp.int32)) - 1  # rank among staged
    # sentinel must be out of range (cap), not -1 (negative indices wrap)
    slot = jnp.where(mask, (ring.head + rank) % cap, cap)
    ring = StagingRing(
        payload=ring.payload.at[slot].set(payload, mode="drop"),
        region=ring.region.at[slot].set(region, mode="drop"),
        offset=ring.offset.at[slot].set(offset, mode="drop"),
        size=ring.size.at[slot].set(size, mode="drop"),
        stag=ring.stag.at[slot].set(stag, mode="drop"),
        live=ring.live.at[slot].set(mask, mode="drop"),
        head=(ring.head + jnp.sum(mask.astype(jnp.int32))) % cap,
    )
    return ring, slot


def need_drain(ring: StagingRing, incoming: int) -> jnp.ndarray:
    """True if appending ``incoming`` more entries could overwrite live data."""
    free = ring.payload.shape[0] - jnp.sum(ring.live.astype(jnp.int32))
    return free < incoming


def drain(
    ring: StagingRing,
    mem: jnp.ndarray,  # [n_regions, region_width] destination memory
    table: U.UMTT,
) -> Tuple[StagingRing, jnp.ndarray, jnp.ndarray]:
    """Target-CPU drain: validate each live entry against uMTT, then copy
    payloads to their destination regions. Returns (empty ring, new mem,
    n_rejected — entries that failed the security check).

    On TPU the copy loop is the ``staged_scatter`` Pallas kernel
    (repro.kernels); this jnp version is its oracle and the CPU path.
    """
    ok = U.validate(table, ring.region, ring.stag) & ring.live
    width = ring.payload.shape[1]
    lane = jnp.arange(width)[None, :]
    elem_mask = ok[:, None] & (lane < ring.size[:, None])

    # scatter rows into mem[region, offset:offset+width] where valid
    # (sentinel = mem.size, out of range -> dropped; -1 would wrap)
    dst_col = ring.offset[:, None] + lane
    flat_idx = jnp.where(
        elem_mask, ring.region[:, None] * mem.shape[1] + dst_col, mem.size
    )
    new_flat = mem.reshape(-1).at[flat_idx.reshape(-1)].set(
        ring.payload.reshape(-1).astype(mem.dtype), mode="drop"
    )
    n_rejected = jnp.sum((ring.live & ~ok).astype(jnp.int32))
    empty = ring._replace(live=jnp.zeros_like(ring.live))
    return empty, new_flat.reshape(mem.shape), n_rejected
