"""Unload module (paper §3.1): the FLAT staging ring + drain, built on the
unified ring abstraction in ``repro.core.ring`` (the KV-cache overlay in
``repro.kvcache.staged`` is the other instantiation — see DESIGN.md §1).

The unload path replaces a write to an arbitrary destination region with

  1. an append into the next slots of a small, reused STAGING RING on the
     target (initiator side: slot allocation + metadata bookkeeping — the
     paper's "rerouting the writeImm to the next slot in the target's
     temporary buffer" and "updating the local metadata about buffer usage");
  2. a target-side DRAIN that (a) validates each staged entry against uMTT
     (address/size/stag/permission — security parity) and (b) copies the
     payload to its true destination (functional parity).

Entries carry (region, offset, size, stag) alongside the payload — the
paper packs the destination address into the writeImm payload and the stag
into the immediate value; we keep them as separate arrays of one staging
record. Cursor/wrap/overflow accounting, conflict detection, uMTT-validated
drain eligibility, and the scatter primitives all come from ``core.ring``;
this module only binds them to the flat (region, offset) address space.

Everything is fixed-shape and jit-compatible; the ring state is a pytree
carried through training/serving steps.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp

from . import ring as R
from . import umtt as U


class StagingRing(NamedTuple):
    """Target-side staging buffer (one per queue pair in the paper).

    Payload + destination metadata are per-entry arrays (ring axis leading);
    occupancy and the append cursor live in the shared ``ring.RingState``.
    """

    payload: jnp.ndarray  # [cap, width] staged payloads
    region: jnp.ndarray   # int32[cap] destination region id
    offset: jnp.ndarray   # int32[cap] element offset within the region
    size: jnp.ndarray     # int32[cap] valid payload elements
    stag: jnp.ndarray     # int32[cap] steering tag for the uMTT check
    state: R.RingState    # shared bookkeeping (live mask + head cursor)

    # Back-compat views (callers/tests predate the unified abstraction).
    @property
    def live(self) -> jnp.ndarray:
        return self.state.live

    @property
    def head(self) -> jnp.ndarray:
        return self.state.head


def make_ring(capacity: int, width: int, dtype=jnp.float32) -> StagingRing:
    return StagingRing(
        payload=jnp.zeros((capacity, width), dtype),
        region=jnp.zeros((capacity,), jnp.int32),
        offset=jnp.zeros((capacity,), jnp.int32),
        size=jnp.zeros((capacity,), jnp.int32),
        stag=jnp.zeros((capacity,), jnp.int32),
        state=R.make(capacity),
    )


def append(
    ring: StagingRing,
    payload: jnp.ndarray,  # [n, width]
    region: jnp.ndarray,
    offset: jnp.ndarray,
    size: jnp.ndarray,
    stag: jnp.ndarray,
    mask: jnp.ndarray,  # bool[n] — which requests take the unload path
) -> Tuple[StagingRing, jnp.ndarray]:
    """Sequential append of masked entries at the ring head.

    Slot assignment (contiguous, wrap-around, sentinel = capacity for
    non-staged requests) is ``ring.append``; this records the flat-ring
    entry record at the assigned slots. Callers drain before overflow
    (``need_drain`` exposes the watermark).
    """
    state, slot = R.append(ring.state, mask)
    recorded = R.record(
        (ring.payload, ring.region, ring.offset, ring.size, ring.stag),
        slot,
        (payload, region, offset, size, stag),
    )
    return StagingRing(*recorded, state=state), slot


def need_drain(ring: StagingRing, incoming: int) -> jnp.ndarray:
    """True if appending ``incoming`` more entries could overwrite live data."""
    return R.need_drain(ring.state, incoming, wrap=True)


def conflicts(ring: StagingRing, region: jnp.ndarray,
              offset: jnp.ndarray) -> jnp.ndarray:
    """True if any incoming (region, offset) destination has a pending
    staged entry (forces a drain first — ordering parity)."""
    return R.conflicts(ring.state, (ring.region, ring.offset), (region, offset))


def drain(
    ring: StagingRing,
    mem: jnp.ndarray,  # [n_regions, region_width] destination memory
    table: U.UMTT,
) -> Tuple[StagingRing, jnp.ndarray, jnp.ndarray]:
    """Target-CPU drain: validate each live entry against uMTT, then copy
    payloads to their destination regions. Returns (empty ring, new mem,
    n_rejected — entries that failed the security check).

    Validation + reject accounting is ``ring.drain_mask``; the copy is
    ``ring.scatter_elems`` (partial-row writes; the same primitive the
    offload path scatters through, so parity is structural). Full-row
    instantiations drain through ``ring.scatter_rows`` -> the
    ``staged_scatter`` Pallas kernel on TPU.
    """
    ok, n_rejected = R.drain_mask(ring.state, table, ring.region, ring.stag)
    mem = R.scatter_elems(mem, ring.payload, ring.region, ring.offset,
                          ring.size, ok)
    empty = ring._replace(state=R.reset(ring.state))  # wrap mode: keep head
    return empty, mem, n_rejected
