"""Decision module (paper §3.2): intercept write requests, consult the
monitor + policy, and emit per-request offload/unload routing decisions.

The module is a thin, jit-compatible composition of ``repro.core.monitor``
and ``repro.core.policy`` — by design: the paper requires decisions "faster
than the expected savings" (hundreds of ns), so the hot path is one counter
update + one compare per request, fully vectorized over the batch.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from .monitor import CMSMonitor, ExactMonitor, calibrate_threshold
from .policy import top_k_hot_table
from .types import PHASE_BULK, DecisionStats, WriteBatch


@dataclasses.dataclass(frozen=True)
class DecisionModule:
    """Routes write batches between the offload and unload paths.

    ``policy.decide`` consumes the (already-updated) monitor state — the
    paper's order: "when a request arrives, uRDMA increments the counter
    corresponding to the remote page ... deciding whether to unload a
    request requires updating one counter and comparing it with the
    threshold".
    """

    policy: object  # any of repro.core.policy.*
    monitor: Optional[object] = None  # ExactMonitor | CMSMonitor

    def init_state(self):
        # STATEFUL policies (e.g. HysteresisPolicy) own their full routing
        # state — monitor counters plus decision memory — behind
        # init_state()/route(); the module just threads it through.
        if hasattr(self.policy, "route"):
            if self.monitor is not None:
                raise ValueError(
                    "stateful policies own their monitor: pass monitor=None "
                    "and configure the monitor on the policy itself "
                    "(a module-level monitor would silently never update)"
                )
            return self.policy.init_state()
        if self.monitor is not None:
            return self.monitor.init()
        return None

    def __call__(
        self, state, batch: WriteBatch, active: Optional[jnp.ndarray] = None
    ) -> Tuple[jnp.ndarray, object, DecisionStats]:
        """-> (unload_mask bool[n], new routing state, stats).

        ``active`` (bool[n], optional) marks live requests in a fixed-shape
        batch (the serve scheduler's slot array): inactive entries never
        update the monitor, never unload, and are excluded from the stats —
        a retired slot's stale region id must not heat a page it no longer
        owns.

        Phase-tagged batches (``batch.phase``): PHASE_BULK entries are
        pinned to the offload path AFTER the policy runs — bulk sequential
        transfers always win on the direct path (the DPU bulk-vs-scattered
        transfer result), so no policy may unload them. They still heat the
        monitor: a prefill-warmed page is hot history the scattered-write
        policy must see. (Stateful policies with per-region decision memory
        record their own verdict; the override is applied to the emitted
        mask, not their memory — bulk writes land on fresh regions whose
        band the next scattered write re-decides anyway.)"""
        if hasattr(self.policy, "route"):
            unload, state = self.policy.route(state, batch, mask=active)
        else:
            if self.monitor is not None:
                state = self.monitor.update(state, batch.region, mask=active)
            unload = self.policy.decide(state, batch)
            if active is not None:
                unload = unload & active
        if batch.phase is not None:
            unload = unload & (batch.phase != PHASE_BULK)
        return unload, state, DecisionStats.from_mask(unload, active,
                                                      batch.phase)


def expert_hot_mask(expert_load: jnp.ndarray, offload_top_k: int) -> jnp.ndarray:
    """bool[E] hot-expert table from accumulated expert-load counters.

    This is the paper's hint/frequency policy applied to MoE expert ids:
    hot (heavy-hitter) experts stay on the direct/offload dispatch path,
    cold experts are staged. Called off the critical path (between steps),
    exactly like the paper's threshold recalibration.
    """
    return top_k_hot_table(expert_load, offload_top_k)


def page_threshold(counts: jnp.ndarray, offload_top_k: int) -> jnp.ndarray:
    """Count threshold putting ~top-k pages on the offload path."""
    return calibrate_threshold(counts, offload_top_k)


__all__ = [
    "DecisionModule",
    "expert_hot_mask",
    "page_threshold",
    "ExactMonitor",
    "CMSMonitor",
]
