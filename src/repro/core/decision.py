"""Decision module (paper §3.2): intercept write requests, consult the
monitor + policy, and emit per-request offload/unload routing decisions.

The module is a thin, jit-compatible composition of ``repro.core.monitor``
and ``repro.core.policy`` — by design: the paper requires decisions "faster
than the expected savings" (hundreds of ns), so the hot path is one counter
update + one compare per request, fully vectorized over the batch.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Tuple

import jax.numpy as jnp

from .monitor import CMSMonitor, ExactMonitor, calibrate_threshold
from .policy import top_k_hot_table
from .types import PHASE_BULK, DecisionStats, WriteBatch


@dataclasses.dataclass(frozen=True)
class DecisionModule:
    """Routes write batches between the offload and unload paths.

    ``policy.decide`` consumes the (already-updated) monitor state — the
    paper's order: "when a request arrives, uRDMA increments the counter
    corresponding to the remote page ... deciding whether to unload a
    request requires updating one counter and comparing it with the
    threshold".
    """

    policy: object  # any RoutingPolicy (repro.core.policy registry)
    monitor: Optional[object] = None  # ExactMonitor | CMSMonitor

    @classmethod
    def from_names(cls, policy: Optional[str] = None, path: str = "direct",
                   *, n_regions: int, hot_threshold: int = 4,
                   **policy_kw) -> "DecisionModule":
        """Registry-driven construction: resolve ``(policy, path)`` name
        strings, negotiate capabilities, return the module. The resolved
        :class:`~repro.core.paths.WritePath` is discarded here — engines
        that also need the path mechanics call
        ``repro.core.paths.build_decision`` directly."""
        from .paths import build_decision  # local: paths imports decision

        _, module = build_decision(path, policy, n_regions=n_regions,
                                   hot_threshold=hot_threshold, **policy_kw)
        return module

    def _policy_owns_state(self) -> bool:
        # STATEFUL policies (e.g. HysteresisPolicy) own their full routing
        # state — monitor counters plus decision memory — behind
        # init_state()/route(); the module just threads it through.
        # Decide-style policies leave counter custody to the module.
        # Third-party policies without a decide() are treated as owning
        # their state (the RoutingPolicy protocol's init_state/route).
        return getattr(self.policy, "owns_state",
                       not hasattr(self.policy, "decide"))

    def init_state(self):
        if self._policy_owns_state():
            if self.monitor is not None:
                raise ValueError(
                    "stateful policies own their monitor: pass monitor=None "
                    "and configure the monitor on the policy itself "
                    "(a module-level monitor would silently never update)"
                )
            return self.policy.init_state()
        if self.monitor is not None:
            return self.monitor.init()
        if hasattr(self.policy, "init_state"):
            return self.policy.init_state()
        return None

    def __call__(
        self, state, batch: WriteBatch, active: Optional[jnp.ndarray] = None
    ) -> Tuple[jnp.ndarray, object, DecisionStats]:
        """-> (unload_mask bool[n], new routing state, stats).

        ``active`` (bool[n], optional) marks live requests in a fixed-shape
        batch (the serve scheduler's slot array): inactive entries never
        update the monitor, never unload, and are excluded from the stats —
        a retired slot's stale region id must not heat a page it no longer
        owns.

        Phase-tagged batches (``batch.phase``): PHASE_BULK entries are
        pinned to the offload path AFTER the policy runs — bulk sequential
        transfers always win on the direct path (the DPU bulk-vs-scattered
        transfer result), so no policy may unload them. They still heat the
        monitor: a prefill-warmed page is hot history the scattered-write
        policy must see. (Stateful policies with per-region decision memory
        record their own verdict; the override is applied to the emitted
        mask, not their memory — bulk writes land on fresh regions whose
        band the next scattered write re-decides anyway.)"""
        if self._policy_owns_state():
            unload, state = self.policy.route(state, batch, mask=active)
        elif self.monitor is not None:
            # decide-style policy with module-owned counters
            state = self.monitor.update(state, batch.region, mask=active)
            unload = self.policy.decide(state, batch)
            if active is not None:
                unload = unload & active
        elif hasattr(self.policy, "route"):
            # no module monitor: the RoutingPolicy adapter keeps custody
            # of whatever monitor the policy itself carries
            unload, state = self.policy.route(state, batch, mask=active)
        else:
            # bare decide-only policy, fully stateless (legal: the
            # pre-registry extension pattern)
            unload = self.policy.decide(state, batch)
            if active is not None:
                unload = unload & active
        if batch.phase is not None:
            unload = unload & (batch.phase != PHASE_BULK)
        return unload, state, DecisionStats.from_mask(unload, active,
                                                      batch.phase)

    def heat(self, state, regions):
        """Off-critical-path monitor heating for bulk writes that bypass
        per-write routing (admission-time prefills): the frequency
        counters must still see every write that lands in a region.
        State-owning policies absorb it via their ``heat(state,
        regions)`` method (HysteresisPolicy implements it); one that
        lacks the method is warned about, since its counters will miss
        all bulk traffic."""
        regions = jnp.asarray(regions, jnp.int32)
        if self.monitor is not None and not self._policy_owns_state():
            return self.monitor.update(state, regions)
        heat = getattr(self.policy, "heat", None)
        if heat is not None:
            return heat(state, regions)
        warnings.warn(
            f"{type(self.policy).__name__} owns its routing state but "
            f"implements no heat(state, regions): bulk prefill writes "
            f"will not warm its counters", stacklevel=2)
        return state


def expert_hot_mask(expert_load: jnp.ndarray, offload_top_k: int) -> jnp.ndarray:
    """bool[E] hot-expert table from accumulated expert-load counters.

    This is the paper's hint/frequency policy applied to MoE expert ids:
    hot (heavy-hitter) experts stay on the direct/offload dispatch path,
    cold experts are staged. Called off the critical path (between steps),
    exactly like the paper's threshold recalibration.
    """
    return top_k_hot_table(expert_load, offload_top_k)


def page_threshold(counts: jnp.ndarray, offload_top_k: int) -> jnp.ndarray:
    """Count threshold putting ~top-k pages on the offload path."""
    return calibrate_threshold(counts, offload_top_k)


__all__ = [
    "DecisionModule",
    "expert_hot_mask",
    "page_threshold",
    "ExactMonitor",
    "CMSMonitor",
]
