"""Unload policies (paper §3.2).

A policy maps a batch of write requests + monitor state to a per-request
routing decision: OFFLOAD (keep on the RNIC / direct path) or UNLOAD
(reroute via the staging buffer + local copy).

Paper-faithful policies:

* ``HintPolicy`` — "assumes the application knows and marks the requests
  that should be offloaded in the RDMA post". We also support the membership
  form used in the evaluation ("offloads only the top-4096 heavy-hitter
  memory regions") via a boolean hot-region table.
* ``FrequencyPolicy`` — "tracks [heavy-hitter pages] using the monitor and
  reroutes requests to the least frequently accessed pages to the unload
  path" — unload iff estimated count < threshold, for small writes only.

Plus trivial ``AlwaysOffload`` / ``AlwaysUnload`` (the paper's orange/green
Fig. 3 lines), and a beyond-paper ``Bandit``-style hysteresis wrapper.

All ``decide`` functions are vectorized and jit-compatible: they must run on
the critical path "faster than the expected savings".

Registry (the serving API's decision plane): every policy conforms to the
:class:`RoutingPolicy` protocol — ``init_state()`` builds the routing
state, ``route(state, batch, mask)`` updates counters and emits the
per-request unload mask — and is registered by name via
:func:`register_policy`, so engines are configured from
``(policy="hysteresis", path="adaptive")`` strings
(``repro.core.paths.build_decision``). A policy declares the decisions it
can emit (``emits``: "offload" / "unload") for capability negotiation
against the write path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Optional, Protocol, Tuple, Union
from typing import runtime_checkable

import jax
import jax.numpy as jnp

from .monitor import CMSMonitor, ExactMonitor, MonitorState
from .types import WriteBatch

Monitor = Union[ExactMonitor, CMSMonitor]

OFFLOADS = frozenset({"offload"})
UNLOADS = frozenset({"unload"})
BOTH_PATHS = OFFLOADS | UNLOADS


@runtime_checkable
class RoutingPolicy(Protocol):
    """The decision-plane contract every registered policy satisfies.

    ``emits`` names the routing decisions the policy can produce (for
    capability negotiation against a ``WritePath``); ``owns_state`` is
    True when ``init_state`` returns more than bare monitor counters (the
    DecisionModule then threads the policy's state object instead of
    owning a monitor itself).
    """

    emits: frozenset
    owns_state: bool

    def init_state(self): ...

    def route(self, state, batch: WriteBatch,
              mask: Optional[jnp.ndarray] = None): ...


class _DecideRoute:
    """RoutingPolicy adapter for decide-style policies: the routing state
    is the (optional) monitor counters; ``route`` = update + decide."""

    owns_state = False

    def init_state(self):
        mon = getattr(self, "monitor", None)
        if self.needs_monitor and mon is not None:
            return mon.init()
        return None

    def route(self, state, batch: WriteBatch,
              mask: Optional[jnp.ndarray] = None):
        mon = getattr(self, "monitor", None)
        if self.needs_monitor and mon is not None:
            state = mon.update(state, batch.region, mask=mask)
        unload = self.decide(state, batch)
        if mask is not None:
            unload = unload & mask
        return unload, state


@dataclasses.dataclass(frozen=True)
class AlwaysOffload(_DecideRoute):
    needs_monitor: bool = False
    emits = OFFLOADS

    def decide(self, state: Optional[MonitorState], batch: WriteBatch) -> jnp.ndarray:
        return jnp.zeros((batch.n,), jnp.bool_)


@dataclasses.dataclass(frozen=True)
class AlwaysUnload(_DecideRoute):
    needs_monitor: bool = False
    emits = UNLOADS

    def decide(self, state: Optional[MonitorState], batch: WriteBatch) -> jnp.ndarray:
        return jnp.ones((batch.n,), jnp.bool_)


@dataclasses.dataclass(frozen=True)
class HintPolicy(_DecideRoute):
    """Offload requests the application marked hot; unload the rest.

    Either consume the per-request ``hint`` field (paper's "marks the
    requests ... in the RDMA post"), or look the region up in a hot-region
    membership table (paper's evaluation: hot = top-4096 regions).
    ``max_unload_size``: only small writes are worth unloading (paper §3.2);
    larger ones stay offloaded regardless of hotness.
    """

    hot_regions: Optional[jnp.ndarray] = None  # bool[n_regions] membership
    max_unload_size: int = 4096
    needs_monitor: bool = False
    emits = BOTH_PATHS

    def decide(self, state: Optional[MonitorState], batch: WriteBatch) -> jnp.ndarray:
        if self.hot_regions is not None:
            hot = self.hot_regions[batch.region]
        else:
            hot = batch.hint.astype(jnp.bool_)
        small = batch.size <= self.max_unload_size
        return (~hot) & small


@dataclasses.dataclass(frozen=True)
class FrequencyPolicy(_DecideRoute):
    """Unload small writes to regions colder than a frequency threshold.

    ``threshold`` is an absolute count; recalibrate it off the critical path
    with ``monitor.calibrate_threshold(counts, offload_top_k)``. ``rel``
    alternatively expresses it relative to the uniform expectation
    (count < rel * total / n_regions).
    """

    monitor: Monitor = dataclasses.field(default_factory=lambda: ExactMonitor(1 << 20))
    threshold: Optional[int] = None
    rel: Optional[float] = None
    n_regions: Optional[int] = None
    max_unload_size: int = 4096
    needs_monitor: bool = True
    emits = BOTH_PATHS

    def decide(self, state: MonitorState, batch: WriteBatch) -> jnp.ndarray:
        est = self.monitor.query(state, batch.region)
        if self.threshold is not None:
            thr = jnp.asarray(self.threshold, jnp.int32)
        elif self.rel is not None:
            n_regions = self.n_regions or getattr(self.monitor, "n_regions", None)
            if n_regions is None:
                raise ValueError("rel threshold needs n_regions")
            thr = (self.rel * state.total.astype(jnp.float32) / n_regions).astype(
                jnp.int32
            )
        else:
            raise ValueError("FrequencyPolicy needs threshold or rel")
        small = batch.size <= self.max_unload_size
        return (est < thr) & small


class HysteresisState(NamedTuple):
    """Carried state for :class:`HysteresisPolicy`: the monitor counters
    plus each region's LAST routing decision (the hysteresis memory)."""

    mon: MonitorState
    last_unload: jnp.ndarray  # bool[n_regions] — True = region was unloaded


@dataclasses.dataclass(frozen=True)
class HysteresisPolicy:
    """Beyond-paper: frequency routing with decision hysteresis.

    Flapping between paths wastes staging-buffer locality; require the
    estimate to clear a margin before switching. Two thresholds: unload
    below ``lo``, offload at/above ``hi``; IN BETWEEN each region keeps its
    last decision (carried in :class:`HysteresisState`). The memory starts
    on the offload side (the safe default — the paper notes blind
    unloading can worsen performance); it only matters in the mid-band,
    since fresh regions sit at count 0 < ``lo`` and unload exactly like
    ``FrequencyPolicy``.

    The last-decision table needs a bounded region universe: ``n_regions``
    (explicit, or taken from an ``ExactMonitor``). Region ids beyond it
    (possible under a ``CMSMonitor``, which exists precisely for huge
    universes) are bucketed ``region % n_regions`` — deterministic
    aliasing of the decision memory, never a silent drop: hysteresis
    still applies per bucket, mirroring how the sketch itself aliases
    counts.
    """

    monitor: Monitor = dataclasses.field(default_factory=lambda: ExactMonitor(1 << 20))
    lo: int = 2
    hi: int = 8
    n_regions: Optional[int] = None
    max_unload_size: int = 4096
    needs_monitor: bool = True
    emits = BOTH_PATHS
    owns_state = True

    def _n_regions(self) -> int:
        n = self.n_regions or getattr(self.monitor, "n_regions", None)
        if n is None:
            raise ValueError(
                "HysteresisPolicy needs n_regions (or an ExactMonitor) "
                "for the last-decision table"
            )
        return int(n)

    def init_state(self) -> HysteresisState:
        return HysteresisState(
            mon=self.monitor.init(),
            last_unload=jnp.zeros((self._n_regions(),), jnp.bool_),
        )

    def _band(self, est: jnp.ndarray, prev: jnp.ndarray) -> jnp.ndarray:
        return jnp.where(est < self.lo, True,
                         jnp.where(est >= self.hi, False, prev))

    def route(self, state: HysteresisState, batch: WriteBatch,
              mask: Optional[jnp.ndarray] = None,
              ) -> Tuple[jnp.ndarray, HysteresisState]:
        """Stateful hot path: update counters, apply the lo/hi bands with
        the carried per-region decision, record the new decisions.

        The memory stores the BAND decision, pre-size-gate: a large write
        is forced onto the offload path but must not flip the region's
        hotness memory — and since duplicates of a region within a batch
        share (est, prev), the recorded value is identical per region
        (deterministic scatter regardless of XLA duplicate-index order).

        ``mask`` (bool[n], optional): masked requests (inactive serve
        slots) update neither the counters nor the decision memory and
        never unload.
        """
        mon = self.monitor.update(state.mon, batch.region, mask=mask)
        est = self.monitor.query(mon, batch.region)
        n = state.last_unload.shape[0]
        bucket = batch.region % n
        prev = state.last_unload[bucket]
        band = self._band(est, prev)
        if mask is None:
            last = state.last_unload.at[bucket].set(band)
        else:
            # masked lanes write NOTHING (out-of-range sentinel drops the
            # scatter) — active duplicates of a region still share
            # (est, prev) and write one identical band value, so the
            # determinism guarantee above survives masking
            last = state.last_unload.at[jnp.where(mask, bucket, n)].set(
                band, mode="drop")
        unload = band & (batch.size <= self.max_unload_size)
        if mask is not None:
            unload = unload & mask
        return unload, HysteresisState(mon, last)

    def heat(self, state: HysteresisState, regions) -> HysteresisState:
        """Off-critical-path counter heating (bulk admission prefills):
        regions warm the monitor without recording a routing decision."""
        return HysteresisState(
            self.monitor.update(state.mon, jnp.asarray(regions, jnp.int32)),
            state.last_unload,
        )

    def decide(self, state, batch: WriteBatch) -> jnp.ndarray:
        """Read-only decision (no counter update, no memory write). Accepts
        either a :class:`HysteresisState` or a bare ``MonitorState`` (then
        mid-band falls back to the safe default, offload)."""
        if isinstance(state, HysteresisState):
            bucket = batch.region % state.last_unload.shape[0]
            mon_state, prev = state.mon, state.last_unload[bucket]
        else:
            mon_state, prev = state, jnp.zeros((batch.n,), jnp.bool_)
        est = self.monitor.query(mon_state, batch.region)
        return self._band(est, prev) & (batch.size <= self.max_unload_size)


# ---------------------------------------------------------------------------
# Registry: RoutingPolicy factories by name
# ---------------------------------------------------------------------------

# factory(monitor=..., n_regions=..., hot_threshold=..., **extra) -> policy.
# Factories receive the engine-supplied context and pick what they need;
# unknown extras are an error (loud beats silent misconfiguration).
_POLICIES: Dict[str, Callable] = {}


def register_policy(name: str, factory: Callable, *,
                    overwrite: bool = False) -> None:
    """Register a :class:`RoutingPolicy` factory under ``name``.

    ``factory(monitor, n_regions, hot_threshold, **extra)`` must return a
    policy satisfying the protocol (``emits``/``init_state``/``route``).
    Third-party policies register here and become constructible from
    config strings everywhere an engine takes ``policy="..."``.
    """
    if name in _POLICIES and not overwrite:
        raise ValueError(
            f"policy {name!r} already registered "
            f"(pass overwrite=True to replace it)")
    _POLICIES[name] = factory


def get_policy_factory(name: str) -> Callable:
    try:
        return _POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r}; registered policies: "
            f"{sorted(_POLICIES)}") from None


def available_policies() -> Tuple[str, ...]:
    return tuple(sorted(_POLICIES))


def _mk_always_offload(monitor=None, n_regions=None, hot_threshold=None):
    return AlwaysOffload()


def _mk_always_unload(monitor=None, n_regions=None, hot_threshold=None):
    return AlwaysUnload()


def _mk_hint(monitor=None, n_regions=None, hot_threshold=None,
             hot_regions=None, max_unload_size=4096):
    return HintPolicy(hot_regions=hot_regions,
                      max_unload_size=max_unload_size)


def _mk_frequency(monitor=None, n_regions=None, hot_threshold=4,
                  max_unload_size=4096):
    monitor = monitor or ExactMonitor(n_regions=n_regions or (1 << 20))
    return FrequencyPolicy(monitor=monitor, threshold=hot_threshold,
                           max_unload_size=max_unload_size)


def _mk_hysteresis(monitor=None, n_regions=None, hot_threshold=4,
                   lo=None, hi=None, max_unload_size=4096):
    monitor = monitor or ExactMonitor(n_regions=n_regions or (1 << 20))
    hi = hi if hi is not None else max(2, int(hot_threshold))
    lo = lo if lo is not None else max(1, hi // 2)
    return HysteresisPolicy(monitor=monitor, lo=lo, hi=hi,
                            n_regions=n_regions,
                            max_unload_size=max_unload_size)


register_policy("always-offload", _mk_always_offload)
register_policy("always-unload", _mk_always_unload)
register_policy("hint", _mk_hint)
register_policy("frequency", _mk_frequency)
register_policy("hysteresis", _mk_hysteresis)


def top_k_hot_table(counts: jnp.ndarray, k: int) -> jnp.ndarray:
    """bool[n_regions] table marking the top-k regions by count.

    Used to build the paper's evaluation policy ("offloads only the top-4096
    heavy-hitter memory regions") from observed or oracle frequencies.
    """
    n = counts.shape[0]
    k = min(int(k), n)
    hot = jnp.zeros((n,), jnp.bool_)
    if k == 0:
        return hot
    _, idx = jax.lax.top_k(counts, k)
    return hot.at[idx].set(True)
