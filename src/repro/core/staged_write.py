"""RemoteWriteEngine — the paper's bidirectional offload, as one API.

``engine.write(state, batch, payload)`` is path-agnostic for callers
(paper Idea 3: "unload through the offload interface"): the decision module
routes each request, the unload module stages + drains, the offload path
scatters directly. Callers receive updated memory and never observe which
path ran — data / final location / security parity are the engine's job.

Destination model: a register-addressed memory of ``n_regions`` regions,
each ``region_width`` elements (the framework instantiates this as KV-cache
pages, expert buffers, or parameter shards). A write = (region, offset,
size<=width, stag, payload[width]).

The OFFLOAD path scatters payloads straight to (region, offset) — dynamic,
destination-order writes (the RNIC-direct analogue). The UNLOAD path appends
to the staging ring and defers placement to a drain (dense, sequential,
validated against uMTT). Drains run when the ring is near capacity or when
``flush`` is called — mirroring the target CPU polling its completion queue.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import ring as R
from . import umtt as U
from . import unload as UL
from .decision import DecisionModule
from .monitor import MonitorState
from .types import WriteBatch


class EngineState(NamedTuple):
    ring: UL.StagingRing
    table: U.UMTT
    monitor: Optional[MonitorState]
    n_offloaded: jnp.ndarray  # int32 running totals (telemetry)
    n_unloaded: jnp.ndarray
    n_rejected: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class RemoteWriteEngine:
    decision: DecisionModule
    ring_capacity: int = 1024
    width: int = 16  # payload elements per write
    dtype: object = jnp.float32

    # -- lifecycle ---------------------------------------------------------
    def init_state(self, table: U.UMTT) -> EngineState:
        return EngineState(
            ring=UL.make_ring(self.ring_capacity, self.width, self.dtype),
            table=table,
            monitor=self.decision.init_state(),
            n_offloaded=jnp.zeros((), jnp.int32),
            n_unloaded=jnp.zeros((), jnp.int32),
            n_rejected=jnp.zeros((), jnp.int32),
        )

    # -- offload path --------------------------------------------------------
    @staticmethod
    def write_direct(
        mem: jnp.ndarray, batch: WriteBatch, payload: jnp.ndarray,
        mask: Optional[jnp.ndarray] = None,
    ) -> jnp.ndarray:
        """Direct scatter to (region, offset). mask selects participating rows.

        Same ``ring.scatter_elems`` primitive the unload path drains
        through — data/final-location parity between paths is structural.
        """
        ok = jnp.ones((batch.n,), jnp.bool_) if mask is None else mask
        return R.scatter_elems(mem, payload, batch.region, batch.offset,
                               batch.size, ok)

    # -- ordering parity (beyond-paper; see DESIGN.md) -----------------------
    @staticmethod
    def _last_wins(batch: WriteBatch) -> jnp.ndarray:
        """bool[n]: False where a LATER write in the same batch hits the same
        (region, offset). Gives deterministic intra-batch last-wins semantics
        across both paths."""
        same = (batch.region[:, None] == batch.region[None, :]) & (
            batch.offset[:, None] == batch.offset[None, :]
        )
        later = jnp.arange(batch.n)[None, :] > jnp.arange(batch.n)[:, None]
        return ~jnp.any(same & later, axis=1)

    @staticmethod
    def _conflicts_ring(ring: UL.StagingRing, batch: WriteBatch) -> jnp.ndarray:
        """True if any incoming write targets a destination with a pending
        (undrained) staged entry — forces a drain first, so cross-batch
        program order per destination is preserved (shared ``ring.conflicts``
        logic, keyed on (region, offset))."""
        return UL.conflicts(ring, batch.region, batch.offset)

    # -- combined write --------------------------------------------------------
    def write(
        self,
        state: EngineState,
        mem: jnp.ndarray,
        batch: WriteBatch,
        payload: jnp.ndarray,
        stag: jnp.ndarray,
    ) -> Tuple[EngineState, jnp.ndarray]:
        """Route a batch of writes. Returns (state, mem).

        ORDERING PARITY (beyond the paper's prototype, which guarantees
        none): (a) within a batch, the last write to a (region, offset)
        wins regardless of path; (b) across batches, a drain is forced
        whenever an incoming write targets a destination with a pending
        staged entry. The paper predicts ordering parity "would likely
        incur a performance penalty" — here it costs one [n x cap] compare
        plus occasional early drains (measured in benchmarks/engine.py).

        Drain-before-overflow is enforced with a fixed-shape ``lax.cond`` so
        the whole engine stays jit/scan-compatible inside serving loops.
        """
        unload_mask, mon, _ = self.decision(state.monitor, batch)
        keep = self._last_wins(batch)

        # drain first if (a) overflow risk or (b) destination conflict
        def do_drain(args):
            ring, m = args
            ring, m, rej = UL.drain(ring, m, state.table)
            return ring, m, rej

        def no_drain(args):
            ring, m = args
            return ring, m, jnp.zeros((), jnp.int32)

        must_drain = UL.need_drain(state.ring, batch.n) | self._conflicts_ring(
            state.ring, batch
        )
        ring, mem, rejected = jax.lax.cond(
            must_drain, do_drain, no_drain, (state.ring, mem)
        )

        # 1) offload subset: direct scatter now
        mem = self.write_direct(mem, batch, payload, ~unload_mask & keep)

        # 2) unload subset: sequential append into the staging ring
        ring, _ = UL.append(
            ring, payload, batch.region, batch.offset, batch.size, stag,
            unload_mask & keep,
        )

        n_u = jnp.sum(unload_mask.astype(jnp.int32))
        new_state = EngineState(
            ring=ring,
            table=state.table,
            monitor=mon,
            n_offloaded=state.n_offloaded + batch.n - n_u,
            n_unloaded=state.n_unloaded + n_u,
            n_rejected=state.n_rejected + rejected,
        )
        return new_state, mem

    def flush(
        self, state: EngineState, mem: jnp.ndarray
    ) -> Tuple[EngineState, jnp.ndarray]:
        """Drain all staged entries (end of step / completion poll)."""
        ring, mem, rejected = UL.drain(state.ring, mem, state.table)
        return state._replace(ring=ring, n_rejected=state.n_rejected + rejected), mem
