"""Heavy-hitter monitor for the decision module (paper §3.2 "Monitor").

The paper tracks remote-page access frequencies with an array of counters,
one per remote 4 KB page, and unloads small writes whose estimated target
pages appear less frequently than a relative-frequency threshold.

We provide two interchangeable monitors:

* ``ExactMonitor`` — the paper's array-of-counters (one int32 per region).
  Cheap when the region universe is known and bounded (it is: registered
  memory regions are known at registration time).
* ``CMSMonitor`` — a count-min sketch for unbounded / huge universes, with
  multiply-shift hashing. This is the variant whose update/query hot path
  we also implement as a Pallas kernel (``repro.kernels.cms``), since the
  paper requires the policy to answer "faster than the expected savings"
  (hundreds of ns).

Both are pure functional: ``update`` returns a new state; ``query`` is
side-effect free. Counters optionally age via periodic halving so the
monitor tracks *current* heavy hitters under drifting workloads.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

# Fixed odd multipliers for multiply-shift hashing (Dietzfelbinger et al.).
_CMS_MULTIPLIERS = (0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F)
_CMS_OFFSETS = (0x165667B1, 0xD3A2646D, 0xFD7046C5, 0xB55A4F09)


class MonitorState(NamedTuple):
    counts: jnp.ndarray  # exact:  int32[n_regions]; cms: int32[depth, width]
    total: jnp.ndarray   # int32 scalar — total writes observed


def _cms_hash(ids: jnp.ndarray, row: int, log2_width: int) -> jnp.ndarray:
    """Multiply-shift hash of int32 ids into [0, 2**log2_width)."""
    x = ids.astype(jnp.uint32)
    a = jnp.uint32(_CMS_MULTIPLIERS[row])
    b = jnp.uint32(_CMS_OFFSETS[row])
    return ((x * a + b) >> jnp.uint32(32 - log2_width)).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class ExactMonitor:
    """One counter per region (paper's baseline monitor)."""

    n_regions: int
    decay_every: int = 0  # 0 = never decay; else halve counters periodically

    def init(self) -> MonitorState:
        return MonitorState(
            counts=jnp.zeros((self.n_regions,), jnp.int32),
            total=jnp.zeros((), jnp.int32),
        )

    def update(
        self,
        state: MonitorState,
        region_ids: jnp.ndarray,
        mask: jnp.ndarray = None,
    ) -> MonitorState:
        """``mask`` (bool[n], optional) drops masked ids from the counters —
        the serve scheduler uses it so retired/empty slots never pollute
        page frequencies (their region ids are stale)."""
        delta = 1 if mask is None else mask.astype(jnp.int32)
        counts = state.counts.at[region_ids].add(delta)
        total = state.total + (
            region_ids.shape[0] if mask is None
            else jnp.sum(mask.astype(jnp.int32))
        )
        if self.decay_every:
            do_decay = (total % self.decay_every) < (state.total % self.decay_every)
            counts = jnp.where(do_decay, counts // 2, counts)
        return MonitorState(counts, total)

    def query(self, state: MonitorState, region_ids: jnp.ndarray) -> jnp.ndarray:
        return state.counts[region_ids]


@dataclasses.dataclass(frozen=True)
class CMSMonitor:
    """Count-min sketch monitor (depth x 2**log2_width)."""

    depth: int = 4
    log2_width: int = 12
    decay_every: int = 0

    def __post_init__(self):
        if not (1 <= self.depth <= len(_CMS_MULTIPLIERS)):
            raise ValueError(f"depth must be in [1, {len(_CMS_MULTIPLIERS)}]")

    @property
    def width(self) -> int:
        return 1 << self.log2_width

    def init(self) -> MonitorState:
        return MonitorState(
            counts=jnp.zeros((self.depth, self.width), jnp.int32),
            total=jnp.zeros((), jnp.int32),
        )

    def update(
        self,
        state: MonitorState,
        region_ids: jnp.ndarray,
        mask: jnp.ndarray = None,
    ) -> MonitorState:
        delta = 1 if mask is None else mask.astype(jnp.int32)
        counts = state.counts
        for r in range(self.depth):
            counts = counts.at[r, _cms_hash(region_ids, r, self.log2_width)].add(delta)
        total = state.total + (
            region_ids.shape[0] if mask is None
            else jnp.sum(mask.astype(jnp.int32))
        )
        if self.decay_every:
            do_decay = (total % self.decay_every) < (state.total % self.decay_every)
            counts = jnp.where(do_decay, counts // 2, counts)
        return MonitorState(counts, total)

    def query(self, state: MonitorState, region_ids: jnp.ndarray) -> jnp.ndarray:
        est = state.counts[0, _cms_hash(region_ids, 0, self.log2_width)]
        for r in range(1, self.depth):
            est = jnp.minimum(
                est, state.counts[r, _cms_hash(region_ids, r, self.log2_width)]
            )
        return est


def calibrate_threshold(counts: jnp.ndarray, offload_top_k: int) -> jnp.ndarray:
    """Pick a count threshold so ~top-k regions stay offloaded.

    The paper: "Good thresholds can be determined out of the critical path by
    looking at the frequency distribution." This helper does exactly that —
    call it off the hot loop (e.g. every N batches) and feed the scalar back
    into ``FrequencyPolicy``.
    """
    k = min(int(offload_top_k), counts.shape[0])
    if k <= 0:
        return jnp.asarray(jnp.iinfo(jnp.int32).max, jnp.int32)
    top = jax.lax.top_k(counts.reshape(-1), k)[0]
    return top[-1].astype(jnp.int32)
