"""Calibrated RDMA-write latency simulator — reproduces the paper's Fig. 3.

No RNIC exists in this container, so the paper's §4 evaluation is reproduced
with a discrete-event model whose constants are calibrated from the paper's
own numbers (≈2.6 µs all-hit RTT, ≈5.1 µs at 2^20 regions, ≈3.4 µs unload,
≈3.5 µs unload at 2^20): see ``repro.core.types.LatencyModel``.

Model components (paper §2 "lifetime of an RDMA write", target side):

* MTT cache — set-associative LRU over region translations at the target
  RNIC. OFFLOADED writes probe/fill it; hit -> t_offload_hit RTT, miss ->
  t_offload_miss (translation fetched over PCIe). UNLOADED writes bypass it:
  they land in the staging ring whose (few, hot) translations stay resident
  — we charge them t_unload_base instead.
* CPU dTLB — the unload path's final memcpy may take "a potential TLB miss"
  (paper §3.1); a second, larger set-associative LRU adds t_cpu_tlb_walk on
  misses. This is what lifts unload from ~3.38 to ~3.5 µs at 2^20 regions.
* Copy cost — payloads beyond the 16 B inlined size add size/copy_gbps.

The simulation scans the write stream sequentially (cache state is genuinely
sequential) under ``lax.scan``; the workload generator reproduces §4: 16 B
inlined writes, destination 4 KB region drawn Zipf(0.5) from R regions.

THE POLICY CODE UNDER TEST IS THE REAL ONE: the adaptive lines in Fig. 3 are
produced by routing each write through ``repro.core.policy`` / ``decision``
exactly as the framework routes KV-cache/MoE writes.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .types import CPUTLBConfig, LatencyModel, MTTConfig, WriteBatch


# ---------------------------------------------------------------------------
# Workload (paper §4)
# ---------------------------------------------------------------------------


def zipf_regions(
    key: jax.Array, n_writes: int, n_regions: int, skew: float = 0.5
) -> jnp.ndarray:
    """Destination regions ~ discrete Zipf(skew) over [0, n_regions)."""
    ranks = jnp.arange(1, n_regions + 1, dtype=jnp.float32)
    weights = ranks ** -skew
    cdf = jnp.cumsum(weights)
    cdf = cdf / cdf[-1]
    u = jax.random.uniform(key, (n_writes,))
    return jnp.searchsorted(cdf, u).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Set-associative LRU cache (MTT / CPU dTLB)
# ---------------------------------------------------------------------------


class CacheState(NamedTuple):
    tags: jnp.ndarray   # int32[n_sets, n_ways], -1 = empty
    stamp: jnp.ndarray  # int32[n_sets, n_ways] — last-use time (LRU)
    clock: jnp.ndarray  # int32 scalar


def make_cache(n_sets: int, n_ways: int) -> CacheState:
    return CacheState(
        tags=jnp.full((n_sets, n_ways), -1, jnp.int32),
        stamp=jnp.zeros((n_sets, n_ways), jnp.int32),
        clock=jnp.zeros((), jnp.int32),
    )


def cache_access(
    state: CacheState, region: jnp.ndarray, enabled: jnp.ndarray
) -> Tuple[CacheState, jnp.ndarray]:
    """One probe+fill. ``enabled`` False leaves the cache untouched (the
    write bypassed this cache). Returns (new state, hit flag)."""
    n_sets, n_ways = state.tags.shape
    s = region % n_sets
    line_tags = state.tags[s]
    line_stamp = state.stamp[s]
    hits = line_tags == region
    hit = jnp.any(hits)
    clock = state.clock + 1
    way_hit = jnp.argmax(hits)
    way_lru = jnp.argmin(line_stamp)
    way = jnp.where(hit, way_hit, way_lru)
    new_tags = line_tags.at[way].set(region)
    new_stamp = line_stamp.at[way].set(clock)
    tags = jnp.where(enabled, state.tags.at[s].set(new_tags), state.tags)
    stamp = jnp.where(enabled, state.stamp.at[s].set(new_stamp), state.stamp)
    return CacheState(tags, stamp, jnp.where(enabled, clock, state.clock)), hit & enabled


# ---------------------------------------------------------------------------
# Simulator
# ---------------------------------------------------------------------------


class SimResult(NamedTuple):
    latency_us: jnp.ndarray   # [n] per-write latency
    mtt_hits: jnp.ndarray     # int32 — offloaded writes that hit the MTT
    n_offloaded: jnp.ndarray  # int32
    n_unloaded: jnp.ndarray   # int32


@dataclasses.dataclass(frozen=True)
class RDMASimulator:
    mtt: MTTConfig = MTTConfig()
    cpu_tlb: CPUTLBConfig = CPUTLBConfig()
    lat: LatencyModel = LatencyModel()

    def run(
        self,
        regions: jnp.ndarray,       # int32[n] destination regions (time order)
        unload_mask: jnp.ndarray,   # bool[n] — decision per write
        sizes: Optional[jnp.ndarray] = None,
    ) -> SimResult:
        n = regions.shape[0]
        if sizes is None:
            sizes = jnp.full((n,), 16, jnp.int32)
        mtt0 = make_cache(self.mtt.n_sets, self.mtt.n_ways)
        tlb0 = make_cache(self.cpu_tlb.n_sets, self.cpu_tlb.n_ways)
        lat = self.lat

        def step(carry, xs):
            mtt, tlb = carry
            region, unload, size = xs
            # offloaded writes probe the RNIC MTT
            mtt, mtt_hit = cache_access(mtt, region, ~unload)
            # unloaded writes take the staged path; the final memcpy
            # probes the CPU dTLB for the destination page
            tlb, tlb_hit = cache_access(tlb, region, unload)
            t_off = jnp.where(mtt_hit, lat.t_offload_hit, lat.t_offload_miss)
            t_un = (
                lat.t_unload_base
                + jnp.where(tlb_hit, 0.0, lat.t_cpu_tlb_walk)
                + lat.unload_copy_us(size)
            )
            t = jnp.where(unload, t_un, t_off)
            return (mtt, tlb), (t, mtt_hit)

        (_, _), (lat_us, mtt_hits) = lax.scan(
            step, (mtt0, tlb0), (regions, unload_mask, sizes)
        )
        n_un = jnp.sum(unload_mask.astype(jnp.int32))
        return SimResult(
            latency_us=lat_us,
            mtt_hits=jnp.sum(mtt_hits.astype(jnp.int32)),
            n_offloaded=n - n_un,
            n_unloaded=n_un,
        )


# ---------------------------------------------------------------------------
# Fig. 3 sweep driver
# ---------------------------------------------------------------------------


def decide_batch(policy, monitor, regions: jnp.ndarray) -> jnp.ndarray:
    """Run the REAL decision module over the whole write stream.

    For the stateless paper policies (hint tables), decisions are per-write
    and order-independent; for frequency policies the monitor is updated
    with the stream (batched — the steady-state approximation of per-write
    updates, valid for the 5M-write steady-state averages Fig. 3 reports).
    """
    batch = WriteBatch(
        region=regions,
        offset=jnp.zeros_like(regions),
        size=jnp.full(regions.shape, 16, jnp.int32),
        hint=jnp.zeros_like(regions),
    )
    state = monitor.init() if monitor is not None else None
    if monitor is not None:
        state = monitor.update(state, regions)
    return policy.decide(state, batch)


def sweep_point(
    key: jax.Array,
    n_regions: int,
    n_writes: int,
    warmup: int,
    policy,
    monitor=None,
    skew: float = 0.5,
    sim: Optional[RDMASimulator] = None,
) -> Tuple[float, SimResult]:
    """Average steady-state RTT (µs) for one Fig. 3 x-axis point."""
    sim = sim or RDMASimulator()
    regions = zipf_regions(key, n_writes, n_regions, skew)
    unload = decide_batch(policy, monitor, regions)
    res = sim.run(regions, unload)
    avg = float(jnp.mean(res.latency_us[warmup:]))
    return avg, res
