"""Core datatypes for the uRDMA bidirectional-offload engine.

Everything is a pure pytree (NamedTuple of jnp arrays) so that the decision
module, monitor, and simulator compose under jit / scan / shard_map.

Conventions
-----------
* Latencies are float32 **microseconds** (matching the paper's Fig. 3 axis).
* Region ids are int32. A "region" is the paper's 4 KB memory region; in the
  framework integration it is a destination page (KV cache) or expert id
  (MoE dispatch).
* Batches of write requests are structure-of-arrays: one array per field.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp

# Path labels (values of a decision mask).
OFFLOAD = 0  # keep on the offloaded (RNIC / direct-scatter) path
UNLOAD = 1   # reroute to the unload (staging buffer + local copy) path

# Write-phase tags (values of ``WriteBatch.phase``). The paper's transfer
# study splits traffic by shape, not source: small scattered writes are the
# unload-path candidates, bulk sequential writes always win on the
# offload/direct path. The serving integration tags each KV write with the
# phase that produced it so the decision plane can apply that rule.
PHASE_SCATTERED = 0  # single-row decode-time write (routing is adaptive)
PHASE_BULK = 1       # contiguous prefill-chunk write (always offload)


class WriteBatch(NamedTuple):
    """A batch of RDMA-write-like requests (structure of arrays).

    region:  int32[n]  destination region / page / expert id
    offset:  int32[n]  byte offset within the region (framework: slot id)
    size:    int32[n]  payload bytes (paper evaluates 16 B inlined writes)
    hint:    int32[n]  application hint: 1 = application marked "offload me"
                       (paper's hint-based policy); 0 = no hint
    phase:   int32[n]  traffic shape tag: PHASE_SCATTERED (decode-style
                       single-row writes, adaptive routing) or PHASE_BULK
                       (prefill-chunk bulk writes, pinned to the offload
                       path). None (legacy constructors) means scattered.
    """

    region: jnp.ndarray
    offset: jnp.ndarray
    size: jnp.ndarray
    hint: jnp.ndarray
    phase: jnp.ndarray = None

    @property
    def n(self) -> int:
        return self.region.shape[0]


def make_write_batch(region, offset=None, size=None, hint=None,
                     phase=None) -> WriteBatch:
    region = jnp.asarray(region, jnp.int32)
    n = region.shape[0]
    if offset is None:
        offset = jnp.zeros((n,), jnp.int32)
    if size is None:
        size = jnp.full((n,), 16, jnp.int32)  # paper: 16 B inlined writes
    if hint is None:
        hint = jnp.zeros((n,), jnp.int32)
    if phase is None:
        phase = jnp.full((n,), PHASE_SCATTERED, jnp.int32)
    return WriteBatch(
        jnp.asarray(region, jnp.int32),
        jnp.asarray(offset, jnp.int32),
        jnp.asarray(size, jnp.int32),
        jnp.asarray(hint, jnp.int32),
        jnp.asarray(phase, jnp.int32),
    )


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    """Calibrated cost model for the two paths (µs), from the paper's text.

    Offload path (one-sided RDMA write, target-side view):
      * MTT hit:   t_offload_hit   (paper: ~2.6 µs RTT with 1 region)
      * MTT miss:  t_offload_miss  (translation fetched over PCIe;
                    calibrated so the Zipf(0.5), 2^20-region mix averages
                    ~5.1 µs as in Fig. 3)
    Unload path (RDMA writeImm into staging ring + CPU copy):
      * base:      t_unload_base   (paper: ~3.4 µs flat)
      * CPU dTLB walk on a cold destination page: t_cpu_tlb_walk
        (the paper notes the final memcpy may take "a potential TLB miss";
        the CPU resolves translations much faster than the RNIC-over-PCIe)
      * copy cost: size / copy_gbps for payloads beyond the inlined 16 B.
    """

    t_offload_hit: float = 2.60
    t_offload_miss: float = 5.13
    t_unload_base: float = 3.38
    t_cpu_tlb_walk: float = 0.12
    copy_gbps: float = 12.0  # memcpy GB/s for the staged->final copy

    def unload_copy_us(self, size_bytes: jnp.ndarray) -> jnp.ndarray:
        extra = jnp.maximum(size_bytes.astype(jnp.float32) - 16.0, 0.0)
        return extra / (self.copy_gbps * 1e3)  # bytes / (GB/s) -> µs


@dataclasses.dataclass(frozen=True)
class MTTConfig:
    """Set-associative model of the RNIC Memory Translation Table cache.

    ConnectX-5-class RNICs cache a few thousand translations; the paper's
    adaptive policy offloads the top-4096 regions and matches the offload
    path at <=2^12 regions, so we default to 4096 entries (512 sets x 8 ways).
    """

    n_sets: int = 512
    n_ways: int = 8

    @property
    def entries(self) -> int:
        return self.n_sets * self.n_ways


@dataclasses.dataclass(frozen=True)
class CPUTLBConfig:
    """CPU-side dTLB model for the unload path's final memcpy.

    Much larger than the RNIC MTT (STLB ~1.5-2K entries) and misses cost a
    page walk from DRAM-adjacent caches, not a PCIe round trip.
    """

    n_sets: int = 256
    n_ways: int = 8


class DecisionStats(NamedTuple):
    """Aggregated routing statistics (for monitoring / EXPERIMENTS.md).

    ``n_bulk`` splits the offloaded tally by phase: bulk (prefill-chunk)
    writes are pinned to the offload path by the decision plane, so
    ``n_offloaded - n_bulk`` is the scattered traffic the policy chose to
    keep direct. Zero when the batch carries no phase tags.
    """

    n_offloaded: jnp.ndarray
    n_unloaded: jnp.ndarray
    n_bulk: jnp.ndarray = jnp.int32(0)

    @staticmethod
    def from_mask(unload_mask: jnp.ndarray, valid=None,
                  phase=None) -> "DecisionStats":
        """``valid`` (bool[n], optional) restricts the tally to live
        requests — inactive serve slots are neither path. ``phase``
        (int32[n], optional) tallies live PHASE_BULK writes separately."""
        u = jnp.sum(unload_mask.astype(jnp.int32))
        nb = jnp.int32(0)
        if phase is not None:
            bulk = phase == PHASE_BULK
            if valid is not None:
                bulk = bulk & valid
            nb = jnp.sum(bulk.astype(jnp.int32))
        if valid is None:
            return DecisionStats(unload_mask.shape[0] - u, u, nb)
        return DecisionStats(jnp.sum(valid.astype(jnp.int32)) - u, u, nb)
