"""Write-path registry: the paper's two-path contract as a pluggable API.

The paper's core requirement is that the offload (direct RDMA scatter)
and unload (staging ring + local copy) paths stay *interchangeable and
compatible* behind one decision plane. This module formalizes that
contract: a :class:`WritePath` declares, by name, HOW writes reach memory
(``uses_ring``: straight scatter vs staging-ring overlay with bulk
drains) and WHICH routing decisions it can absorb (``capabilities``), and
engines are configured from ``(path="adaptive", policy="hysteresis")``
strings resolved through the registry — so a new backend is a
registration, not an engine fork.

Capabilities
------------
``direct``    the path can land a scattered write straight at its final
              destination (the offload/RNIC path).
``staged``    the path can absorb a write into the staging ring and drain
              it later (the unload path; implies drain machinery).
``bulk-pin``  bulk/contiguous (prefill-phase) writes can be pinned to the
              direct path even while scattered traffic stages — required
              for chunked prefill, where the decision plane tags
              PHASE_BULK writes.

Negotiation (:func:`negotiate`) errors loudly on incompatible combos:
a policy that may emit "unload" needs a ``staged``-capable path, a policy
that may emit "offload" needs ``direct`` support (``bulk-pin`` covers
only phase-tagged bulk writes), the dense-lane KV layout only takes
pure-direct paths, and chunked prefill needs ``bulk-pin``.

Built-ins mirror the legacy ``write_mode`` strings: ``direct`` /
``staged`` / ``adaptive`` — old configs keep meaning the same thing.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional, Tuple, Union

from .decision import DecisionModule
from .monitor import ExactMonitor
from .policy import get_policy_factory

CAP_DIRECT = "direct"
CAP_STAGED = "staged"
CAP_BULK_PIN = "bulk-pin"
_KNOWN_CAPS = frozenset({CAP_DIRECT, CAP_STAGED, CAP_BULK_PIN})


@dataclasses.dataclass(frozen=True)
class WritePath:
    """A named KV/memory write mechanism and its negotiation surface.

    name            registry key (and the engine config string).
    capabilities    subset of {direct, staged, bulk-pin} — the decisions
                    this path can absorb.
    uses_ring       True = writes may ride the staging-ring overlay and
                    the engine must run drain machinery (full-ring,
                    conflict-forced, and segment-boundary drains).
    default_policy  RoutingPolicy name paired with this path when the
                    caller names no policy.
    description     one-liner for error messages / docs.
    """

    name: str
    capabilities: frozenset
    uses_ring: bool
    default_policy: str
    description: str = ""

    def __post_init__(self):
        unknown = set(self.capabilities) - _KNOWN_CAPS
        if unknown:
            raise ValueError(
                f"write path {self.name!r}: unknown capabilities "
                f"{sorted(unknown)} (known: {sorted(_KNOWN_CAPS)})")
        if CAP_STAGED in self.capabilities and not self.uses_ring:
            raise ValueError(
                f"write path {self.name!r}: the 'staged' capability "
                f"requires uses_ring=True (staged writes need the ring "
                f"overlay + drain machinery)")

    def supports(self, cap: str) -> bool:
        return cap in self.capabilities

    def __repr__(self) -> str:
        # deterministic (sorted) capability order: this repr lands in
        # error messages and the committed public-API snapshot
        caps = ", ".join(sorted(self.capabilities))
        return (f"WritePath(name={self.name!r}, capabilities={{{caps}}}, "
                f"uses_ring={self.uses_ring}, "
                f"default_policy={self.default_policy!r})")


_PATHS: Dict[str, WritePath] = {}


def register_path(path: WritePath, *, overwrite: bool = False) -> WritePath:
    """Register a write path by its name. Third-party paths registered
    here are constructible from ``path="..."`` strings in every engine
    config (the registry IS the extension point)."""
    if path.name in _PATHS and not overwrite:
        raise ValueError(
            f"write path {path.name!r} already registered "
            f"(pass overwrite=True to replace it)")
    _PATHS[path.name] = path
    return path


def get_path(name: Union[str, WritePath]) -> WritePath:
    if isinstance(name, WritePath):
        return name
    try:
        return _PATHS[name]
    except KeyError:
        raise ValueError(
            f"unknown write path {name!r}; registered paths: "
            f"{sorted(_PATHS)}") from None


def available_paths() -> Tuple[str, ...]:
    return tuple(sorted(_PATHS))


DIRECT = register_path(WritePath(
    name="direct",
    capabilities=frozenset({CAP_DIRECT, CAP_BULK_PIN}),
    uses_ring=False,
    default_policy="always-offload",
    description="per-write scatter straight to the destination "
                "(the offload/RNIC path)",
))

STAGED = register_path(WritePath(
    name="staged",
    capabilities=frozenset({CAP_STAGED, CAP_BULK_PIN}),
    uses_ring=True,
    default_policy="always-unload",
    description="staging-ring append + bulk drain for every scattered "
                "write (the unload path)",
))

ADAPTIVE = register_path(WritePath(
    name="adaptive",
    capabilities=frozenset({CAP_DIRECT, CAP_STAGED, CAP_BULK_PIN}),
    uses_ring=True,
    default_policy="frequency",
    description="per-write routing between direct scatter and the "
                "staging ring (the paper's composite)",
))


def negotiate(path: WritePath, policy, *, layout: Optional[str] = None,
              chunked: bool = False) -> None:
    """Validate a (path, policy, layout, scheduling) combination.

    Raises ``ValueError`` with the full incompatibility story — which
    capability is missing and what would need to change — instead of
    letting an unsupported combination mis-route writes at runtime.
    """
    emits = getattr(policy, "emits", frozenset({"offload", "unload"}))
    pname = getattr(policy, "name", type(policy).__name__)
    if "unload" in emits and not path.supports(CAP_STAGED):
        raise ValueError(
            f"policy {pname} can route writes to the unload path, but "
            f"write path {path.name!r} lacks the 'staged' capability "
            f"(capabilities: {sorted(path.capabilities)}); pick a "
            f"staged-capable path or an offload-only policy")
    if "offload" in emits and not path.supports(CAP_DIRECT):
        raise ValueError(
            f"policy {pname} can keep scattered writes on the offload "
            f"path, but write path {path.name!r} lacks the 'direct' "
            f"capability (capabilities: {sorted(path.capabilities)}; "
            f"'bulk-pin' covers only phase-tagged bulk writes); pick a "
            f"direct-capable path or an unload-only policy")
    if layout == "lanes" and path.supports(CAP_STAGED):
        raise ValueError(
            f"kv_layout='lanes' is direct-only (per-slot cache lanes "
            f"have no ring overlay), but write path {path.name!r} "
            f"carries the 'staged' capability; use path='direct' or the "
            f"paged layout")
    if chunked and not path.supports(CAP_BULK_PIN):
        raise ValueError(
            f"chunked prefill tags bulk writes for offload-path pinning, "
            f"but write path {path.name!r} lacks the 'bulk-pin' "
            f"capability (capabilities: {sorted(path.capabilities)})")


ATTN_FUSED = "fused"
ATTN_REFERENCE = "reference"
_KNOWN_ATTN = ("auto", ATTN_FUSED, ATTN_REFERENCE)


def resolve_attention(attention: str = "auto", *,
                      layout: Optional[str] = None,
                      arch_paged_capable: bool = True,
                      backend: Optional[str] = None) -> str:
    """Negotiate the decode-attention implementation, mirroring
    :func:`negotiate`'s loud-error contract.

    ``fused`` is the ``flash_decode_paged`` read kernel: a scalar-prefetch
    page-table walk over the physical pool with the staging ring as a
    second softmax source. It REQUIRES the paged layout (the dense-lane
    layout has no page table to walk) — requesting it elsewhere is a
    config error, not a silent fallback. ``auto`` picks fused wherever the
    kernel compiles natively (any non-CPU backend serving a paged cache)
    and the reference jnp path on CPU, where interpret mode is the
    validation lane, not a serving path. CI sets ``REPRO_ATTENTION=fused``
    to force the kernel (interpret mode) through ``auto`` configs so CPU
    jobs exercise the fused read path end to end.
    """
    if attention not in _KNOWN_ATTN:
        raise ValueError(
            f"unknown attention implementation {attention!r} "
            f"(known: {list(_KNOWN_ATTN)})")
    paged = layout == "paged" and arch_paged_capable
    if attention == ATTN_FUSED and not paged:
        raise ValueError(
            f"attention='fused' needs the paged KV layout to walk "
            f"(layout={layout!r}, paged-capable={arch_paged_capable}); "
            f"use kv_layout='paged' on a dense decoder arch, or "
            f"attention='reference'")
    if attention != "auto":
        return attention
    if not paged:
        return ATTN_REFERENCE
    env = os.environ.get("REPRO_ATTENTION")
    if env is not None:
        return resolve_attention(env, layout=layout,
                                 arch_paged_capable=arch_paged_capable,
                                 backend=backend)
    if backend is None:
        import jax

        backend = jax.default_backend()
    return ATTN_FUSED if backend != "cpu" else ATTN_REFERENCE


def build_decision(path: Union[str, WritePath] = "direct",
                   policy: Optional[str] = None, *,
                   n_regions: int,
                   hot_threshold: int = 4,
                   layout: Optional[str] = None,
                   chunked: bool = False,
                   **policy_kw) -> Tuple[WritePath, DecisionModule]:
    """The one (path, policy) -> decision-plane factory.

    Resolves both names through their registries, negotiates capabilities
    (loud errors on incompatible combos), and assembles the
    :class:`DecisionModule`: policies that own their routing state
    (``owns_state``) keep their monitor to themselves; decide-style
    policies share the module-level monitor so every write heats the
    same counters the engine reads for telemetry.
    """
    wp = get_path(path)
    pol_name = policy or wp.default_policy
    factory = get_policy_factory(pol_name)
    monitor = ExactMonitor(n_regions=n_regions)
    pol = factory(monitor=monitor, n_regions=n_regions,
                  hot_threshold=hot_threshold, **policy_kw)
    negotiate(wp, pol, layout=layout, chunked=chunked)
    if getattr(pol, "owns_state", not hasattr(pol, "decide")):
        module = DecisionModule(policy=pol)
    else:
        module = DecisionModule(policy=pol, monitor=monitor)
    return wp, module


__all__ = [
    "CAP_DIRECT", "CAP_STAGED", "CAP_BULK_PIN",
    "ATTN_FUSED", "ATTN_REFERENCE",
    "WritePath", "register_path", "get_path", "available_paths",
    "DIRECT", "STAGED", "ADAPTIVE",
    "negotiate", "resolve_attention", "build_decision",
]
