"""uMTT — uRDMA's software memory-registration map (paper §3.1).

"To guarantee security parity, the address, size, stag, and permission
metadata for each memory region registration are stored in uMTT, a uRDMA
local map, and removed during de-registration. The security check ... is
performed via a lookup into this map."

The map is a fixed-capacity structure-of-arrays so that batched validation
jits: each unloaded write is checked (region/address range, stag match,
write permission) before the drain copies it to its final destination.
Registration/deregistration are host-side (setup-time) operations, mirroring
RDMA memory registration being off the critical path.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp

PERM_READ = 1
PERM_WRITE = 2


class UMTT(NamedTuple):
    """Registration table. Rows with valid==0 are free slots."""

    base: jnp.ndarray   # int32[cap] — first region id of the registration
    limit: jnp.ndarray  # int32[cap] — one past the last region id
    stag: jnp.ndarray   # int32[cap] — steering tag handed to initiators
    perm: jnp.ndarray   # int32[cap] — PERM_* bitmask
    valid: jnp.ndarray  # bool[cap]


def make_umtt(capacity: int = 4096) -> UMTT:
    z = jnp.zeros((capacity,), jnp.int32)
    return UMTT(z, z, z, z, jnp.zeros((capacity,), jnp.bool_))


def register(
    table: UMTT, base: int, n_regions: int, stag: int, perm: int = PERM_WRITE
) -> UMTT:
    """Register [base, base+n_regions) under ``stag``. Host-side (setup)."""
    free = jnp.argmin(table.valid)  # first free slot (valid is bool)
    # refuse to overwrite a live slot (table full)
    occupied = table.valid[free]
    new = UMTT(
        table.base.at[free].set(jnp.where(occupied, table.base[free], base)),
        table.limit.at[free].set(
            jnp.where(occupied, table.limit[free], base + n_regions)
        ),
        table.stag.at[free].set(jnp.where(occupied, table.stag[free], stag)),
        table.perm.at[free].set(jnp.where(occupied, table.perm[free], perm)),
        table.valid.at[free].set(True),
    )
    return new


def deregister(table: UMTT, stag: int) -> UMTT:
    """Remove all registrations carrying ``stag`` (paper: removed at dereg)."""
    hit = table.valid & (table.stag == stag)
    return table._replace(valid=table.valid & ~hit)


def validate(
    table: UMTT,
    region: jnp.ndarray,
    stag: jnp.ndarray,
    need_perm: int = PERM_WRITE,
) -> jnp.ndarray:
    """Batched security check for unloaded writes.

    region/stag: int32[n]. True where some live registration covers the
    region, carries the same stag, and grants ``need_perm``. This is the
    paper's replacement for the RNIC-side MTT protection check.
    """
    r = region[:, None]
    s = stag[:, None]
    ok = (
        table.valid[None, :]
        & (r >= table.base[None, :])
        & (r < table.limit[None, :])
        & (s == table.stag[None, :])
        & ((table.perm[None, :] & need_perm) == need_perm)
    )
    return jnp.any(ok, axis=1)


def occupancy(table: UMTT) -> Tuple[jnp.ndarray, int]:
    return jnp.sum(table.valid), table.valid.shape[0]
