"""uRDMA core: the paper's contribution as a composable JAX module.

Pieces (paper section in brackets):
  types        WriteBatch / LatencyModel / cache configs
  monitor      heavy-hitter counters: exact array + count-min sketch [§3.2]
  policy       AlwaysOffload/AlwaysUnload/Hint/Frequency/Hysteresis [§3.2]
  decision     DecisionModule — per-request offload/unload routing [§3.2]
  umtt         software registration map (security parity) [§3.1]
  unload       staging ring buffer + validated drain [§3.1]
  staged_write RemoteWriteEngine — the bidirectional write API [§3]
  paths        WritePath registry + capability negotiation [§3]
  simulator    calibrated MTT/PCIe latency model -> Fig. 3 repro [§4]
"""
from .decision import DecisionModule, expert_hot_mask, page_threshold
from .monitor import CMSMonitor, ExactMonitor, MonitorState, calibrate_threshold
from .paths import (
    WritePath,
    available_paths,
    build_decision,
    get_path,
    negotiate,
    register_path,
)
from .policy import (
    AlwaysOffload,
    AlwaysUnload,
    FrequencyPolicy,
    HintPolicy,
    HysteresisPolicy,
    RoutingPolicy,
    available_policies,
    get_policy_factory,
    register_policy,
    top_k_hot_table,
)
from .simulator import RDMASimulator, SimResult, sweep_point, zipf_regions
from .staged_write import EngineState, RemoteWriteEngine
from .types import (
    OFFLOAD,
    UNLOAD,
    CPUTLBConfig,
    DecisionStats,
    LatencyModel,
    MTTConfig,
    WriteBatch,
    make_write_batch,
)
from .umtt import PERM_READ, PERM_WRITE, UMTT, deregister, make_umtt, register, validate
from .unload import StagingRing, append, drain, make_ring, need_drain

__all__ = [
    "DecisionModule", "expert_hot_mask", "page_threshold",
    "CMSMonitor", "ExactMonitor", "MonitorState", "calibrate_threshold",
    "AlwaysOffload", "AlwaysUnload", "FrequencyPolicy", "HintPolicy",
    "HysteresisPolicy", "RoutingPolicy", "top_k_hot_table",
    "register_policy", "get_policy_factory", "available_policies",
    "WritePath", "register_path", "get_path", "available_paths",
    "negotiate", "build_decision",
    "RDMASimulator", "SimResult", "sweep_point", "zipf_regions",
    "EngineState", "RemoteWriteEngine",
    "OFFLOAD", "UNLOAD", "CPUTLBConfig", "DecisionStats", "LatencyModel",
    "MTTConfig", "WriteBatch", "make_write_batch",
    "PERM_READ", "PERM_WRITE", "UMTT", "deregister", "make_umtt", "register",
    "validate",
    "StagingRing", "append", "drain", "make_ring", "need_drain",
]
