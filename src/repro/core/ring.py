"""Unified staging-ring abstraction — THE single implementation of the
paper's §3.1 unload-path machinery (see DESIGN.md §1).

Every unload path in the repo — the flat ``RemoteWriteEngine`` memory ring
(``core.unload`` / ``core.staged_write``) and the decode-time KV-cache
overlay (``kvcache.staged``) — is an *instantiation* of this module. What
exists exactly once here:

* **cursor / wrap / overflow accounting** — :func:`assign_slots`,
  :func:`free_slots`, :func:`free_ahead`, :func:`need_drain`, :func:`full`;
* **conflict detection** (destination already staged and undrained ->
  forced drain preserves cross-batch program order) — :func:`conflicts`;
* **uMTT validation + reject accounting** at drain time —
  :func:`drain_mask`;
* **the drain copy** — :func:`scatter_rows` (full-row entries; dispatches
  to the ``staged_scatter`` Pallas kernel on TPU) and :func:`scatter_elems`
  (partial-row entries; the jnp oracle, and also the OFFLOAD path's direct
  scatter, so both paths land in memory through the same primitive —
  ordering/functional parity by construction).

State model
-----------
:class:`RingState` carries only the bookkeeping every ring shares: a
``live`` occupancy mask and the ``head`` append cursor. Per-entry
*metadata* (destination region/offset/stag for the flat ring, destination
cache slot for the KV ring) and *payload planes* (packed rows, or the
[L, B, R, H, Dh] KV tiles) have instantiation-specific shapes; they live
with the instantiation and are updated through :func:`record` /
:func:`push_column` at slots this module assigns. The ring axis is ALWAYS
the last axis of ``live`` (lead axes, e.g. batch lanes, broadcast before
it). Everything is fixed-shape and jit/scan-compatible.

Two accounting modes (both drain-before-overflow, DESIGN.md §1.2):

* **wrap** (flat engine): slots are reused after a drain; the cursor keeps
  advancing modulo capacity and occupancy is counted from ``live``.
* **dense** (KV overlay): entries are appended at 0..head and the whole
  ring is reset (head -> 0) on drain, so ``capacity - head`` columns remain.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import umtt as U


class RingState(NamedTuple):
    """Shared staging-ring bookkeeping.

    live: bool[..., cap] — slot holds an undrained entry (ring axis last;
          optional lead axes are per-lane validity, e.g. per batch row).
    head: int32 scalar — next append position (modulo capacity).
    """

    live: jnp.ndarray
    head: jnp.ndarray


def make(capacity: int, lead: Tuple[int, ...] = ()) -> RingState:
    return RingState(
        live=jnp.zeros(lead + (capacity,), jnp.bool_),
        head=jnp.zeros((), jnp.int32),
    )


def capacity(state: RingState) -> int:
    return state.live.shape[-1]


def dense_state(meta: jnp.ndarray, fill: jnp.ndarray) -> RingState:
    """Dense-mode bookkeeping view from an instantiation's metadata plane:
    ``meta`` [lanes, cap] per-entry destination keys (-1 = lane not staged
    at that column), ``fill`` the scalar append cursor. THE one way both KV
    overlays (``kvcache.staged`` on main-cache slots, ``kvcache.paged`` on
    logical rows) derive their RingState — the occupancy rule ("columns
    [0, fill) where a destination was recorded") exists only here."""
    cap = meta.shape[1]
    filled = jnp.arange(cap)[None, :] < fill
    return RingState(live=filled & (meta >= 0), head=fill)


# ---------------------------------------------------------------------------
# occupancy / overflow accounting
# ---------------------------------------------------------------------------


def _column_used(state: RingState) -> jnp.ndarray:
    """bool[cap]: column holds a live entry in any lane."""
    used = state.live
    while used.ndim > 1:
        used = jnp.any(used, axis=0)
    return used


def free_slots(state: RingState) -> jnp.ndarray:
    """Wrap mode: columns holding no live entry (reusable after drain)."""
    return capacity(state) - jnp.sum(_column_used(state).astype(jnp.int32))


def free_ahead(state: RingState) -> jnp.ndarray:
    """Dense mode: columns ahead of the cursor (ring resets on drain)."""
    return capacity(state) - state.head


def need_drain(state: RingState, incoming, *, wrap: bool = True) -> jnp.ndarray:
    """True if appending ``incoming`` more entries could overwrite live data."""
    free = free_slots(state) if wrap else free_ahead(state)
    return free < incoming


def full(state: RingState, *, wrap: bool = True) -> jnp.ndarray:
    return need_drain(state, 1, wrap=wrap)


# ---------------------------------------------------------------------------
# append
# ---------------------------------------------------------------------------


def assign_slots(state: RingState, mask: jnp.ndarray) -> jnp.ndarray:
    """Slots for a masked batched append: slot = head + rank among staged.

    Staging writes are CONTIGUOUS by construction (this is the whole point:
    the ring is small and sequentially written, hence "MTT-cache-resident"
    in the paper and dense/fusable on TPU). Non-staged requests get the
    out-of-range sentinel ``capacity`` (NOT -1: negative indices wrap).
    """
    cap = capacity(state)
    rank = jnp.cumsum(mask.astype(jnp.int32)) - 1
    return jnp.where(mask, (state.head + rank) % cap, cap)


def append(state: RingState, mask: jnp.ndarray) -> Tuple[RingState, jnp.ndarray]:
    """Assign slots for the masked entries, mark them live, advance the
    cursor. Returns (state, slots[n] with sentinel=capacity)."""
    cap = capacity(state)
    slots = assign_slots(state, mask)
    state = RingState(
        live=state.live.at[..., slots].set(mask, mode="drop"),
        head=(state.head + jnp.sum(mask.astype(jnp.int32))) % cap,
    )
    return state, slots


def record(arrays, slots: jnp.ndarray, values) -> "jax.Array | tuple | dict":
    """Scatter per-entry metadata/payload ``values`` (pytree of [n, ...]) into
    ring-axis-LEADING ``arrays`` ([cap, ...]) at ``slots`` (sentinel drops)."""
    return jax.tree.map(
        lambda buf, v: buf.at[slots].set(v, mode="drop"), arrays, values
    )


def push_column(buf: jnp.ndarray, head: jnp.ndarray, column: jnp.ndarray,
                axis: int = -1) -> jnp.ndarray:
    """Write one entry ``column`` at ring position ``head`` of ``buf``.

    ``axis`` locates the ring axis in ``buf``; ``column`` is ``buf`` without
    that axis (lane-style metadata like [B, cap] slot tables, or payload
    planes like [B, R, H, Dh] with axis=1).
    """
    axis = axis % buf.ndim
    starts = [jnp.zeros((), jnp.int32)] * buf.ndim
    starts[axis] = head
    return lax.dynamic_update_slice(buf, jnp.expand_dims(column, axis),
                                    tuple(starts))


def shadow_mask(
    live: jnp.ndarray,        # bool [lanes, cap]
    rows: jnp.ndarray,        # int32 [lanes, cap] per-entry destination rows
    width: int,               # destination row universe per lane
    extra_rows: Optional[jnp.ndarray] = None,  # int32 [lanes], sentinel=width
) -> jnp.ndarray:
    """bool [lanes, width]: destination rows whose AUTHORITATIVE value is a
    live staged entry (the ring holds it until drained) — these must be
    excluded from the destination-side validity mask. ``extra_rows`` adds
    one per-lane row (e.g. the entry being staged this step); the sentinel
    ``width`` means none. The ONE shadowing implementation — both KV
    overlays (dense-lane and paged-pool) build their attention masks on it."""
    lanes = live.shape[0]
    src = jnp.where(live, rows, width)
    out = jnp.zeros((lanes, width + 1), jnp.bool_)
    out = out.at[jnp.arange(lanes)[:, None], src].set(True)
    if extra_rows is not None:
        out = out.at[jnp.arange(lanes), extra_rows].set(True)
    return out[:, :width]


def merge_lanes(state: RingState,
                rows: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Flatten a multi-lane ring into ONE entry list for a pooled drain.

    ``state.live`` [lanes, cap] and per-entry destination ``rows``
    [lanes, cap] flatten (C order — lane-major, matching a
    ``payload.reshape(lanes * cap, ...)`` of the payload planes) to
    (rows [lanes*cap], ok [lanes*cap]). Use when every lane drains into
    the SAME destination memory (e.g. the paged KV pool) so the whole
    drain is one :func:`scatter_rows` instead of a vmap of per-lane
    scatters. The caller guarantees cross-lane destination uniqueness
    (for the paged pool: block ownership)."""
    return rows.reshape(-1), state.live.reshape(-1)


def reset(state: RingState, *, rewind: bool = False) -> RingState:
    """Empty the ring after a drain. ``rewind`` resets the cursor too
    (dense mode); wrap mode keeps it (slots are reused in place)."""
    return RingState(
        live=jnp.zeros_like(state.live),
        head=jnp.zeros_like(state.head) if rewind else state.head,
    )


# ---------------------------------------------------------------------------
# conflict detection (ordering parity, DESIGN.md §1.3)
# ---------------------------------------------------------------------------


def conflicts(
    state: RingState,
    stored_keys: Sequence[jnp.ndarray],
    incoming_keys: Sequence[jnp.ndarray],
) -> jnp.ndarray:
    """True if any incoming write targets a destination with a pending
    (undrained) staged entry — the caller must drain first so cross-batch
    program order per destination is preserved.

    ``stored_keys``: per-entry destination key components, each shaped like
    ``state.live`` ([..., cap]). ``incoming_keys``: matching components of
    the incoming writes, each [..., n] (lead axes as in ``live``). A
    conflict needs ALL components equal on a live entry.
    """
    hit = state.live[..., None, :]  # [..., 1, cap]
    for stored, incoming in zip(stored_keys, incoming_keys):
        hit = hit & (incoming[..., :, None] == stored[..., None, :])
    return jnp.any(hit)


# ---------------------------------------------------------------------------
# drain: uMTT validation + the two scatter primitives
# ---------------------------------------------------------------------------


def drain_mask(
    state: RingState,
    table: Optional[U.UMTT],
    region: Optional[jnp.ndarray] = None,
    stag: Optional[jnp.ndarray] = None,
    *,
    need_perm: int = U.PERM_WRITE,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-entry drain eligibility: live AND (when a uMTT is attached)
    passing the security check. Returns (ok mask, n_rejected).

    This is the ONE place staged entries meet the uMTT (security parity,
    paper §3.1): every instantiation's drain routes through here. With
    ``table=None`` (trusted instantiations, e.g. the in-model KV overlay
    whose destinations are engine-computed, never initiator-supplied) all
    live entries are eligible and nothing is rejected.
    """
    if table is None:
        return state.live, jnp.zeros((), jnp.int32)
    ok = U.validate(table, region, stag, need_perm=need_perm) & state.live
    n_rejected = jnp.sum((state.live & ~ok).astype(jnp.int32))
    return ok, n_rejected


def scatter_rows(
    dest: jnp.ndarray,     # [R, W]
    staging: jnp.ndarray,  # [N, W]
    rows: jnp.ndarray,     # int32[N]
    ok: jnp.ndarray,       # bool[N]
    *,
    use_kernel: bool = False,
    interpret: bool = False,
) -> jnp.ndarray:
    """Full-row drain: staged row i -> dest[rows[i]] where ok[i].

    The single dispatch point for the ``staged_scatter`` Pallas kernel
    (TPU path); the jnp branch is its oracle and the CPU path.
    PRECONDITION (DESIGN.md §2): valid rows are unique within one drain —
    guaranteed by conflict-forced drains (:func:`conflicts`).
    """
    if use_kernel:
        if interpret:  # forced interpret mode (kernel-vs-oracle tests)
            from ..kernels.staged_scatter import staged_scatter as _raw

            return _raw(dest, staging, rows, ok, interpret=True)
        from ..kernels import staged_scatter  # ops wrapper: TPU kernel,
                                              # interpret/ref on CPU
        return staged_scatter(dest, staging, rows, ok)
    idx = jnp.where(ok, rows, dest.shape[0])  # sentinel past the end drops
    return dest.at[idx].set(
        staging.astype(dest.dtype), mode="drop", unique_indices=True
    )


def scatter_elems(
    mem: jnp.ndarray,      # [n_regions, region_width]
    payload: jnp.ndarray,  # [N, width]
    region: jnp.ndarray,   # int32[N]
    offset: jnp.ndarray,   # int32[N]
    size: jnp.ndarray,     # int32[N]
    ok: jnp.ndarray,       # bool[N]
) -> jnp.ndarray:
    """Partial-row scatter: payload[i, :size[i]] -> mem[region[i],
    offset[i]:offset[i]+size[i]] where ok[i].

    Used by BOTH the flat ring's drain and the offload path's direct
    scatter (``RemoteWriteEngine.write_direct``) — data/final-location
    parity between the two paths is structural, not tested-for.
    """
    width = payload.shape[1]
    lane = jnp.arange(width)[None, :]
    elem = ok[:, None] & (lane < size[:, None])
    # sentinel must be OUT OF RANGE (mem.size), not -1 (negative wraps!)
    flat_idx = jnp.where(
        elem, region[:, None] * mem.shape[1] + offset[:, None] + lane, mem.size
    )
    new_flat = mem.reshape(-1).at[flat_idx.reshape(-1)].set(
        payload.reshape(-1).astype(mem.dtype), mode="drop"
    )
    return new_flat.reshape(mem.shape)
