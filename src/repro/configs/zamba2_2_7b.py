"""zamba2-2.7b [hybrid] — Mamba2 trunk + shared attention block.
[arXiv:2411.15242; hf:Zyphra/Zamba2-2.7B]

54L d_model=2560 (mamba2 blocks, ssm_state=64) with one SHARED
attention+MLP block (32H, kv=32, d_ff=10240) applied every 6th layer —
the zamba2 "shared transformer block" design: its weights are reused at
every application. vocab=32000.
"""
from .base import HYBRID, SWIGLU, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family=HYBRID,
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    activation=SWIGLU,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=256,
    hybrid_attn_every=6,
    rope_theta=10_000.0,
)
