"""stablelm-1.6b [dense] — MHA (kv=32), partial rotary, LayerNorm.
[hf:stabilityai/stablelm-2-1_6b]

24L d_model=2048 32H (GQA kv=32 == MHA) d_ff=5632 vocab=100352.
StableLM-2 uses LayerNorm (not RMSNorm) and 25% partial rotary embeddings.
"""
from .base import DENSE, LAYERNORM, SWIGLU, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family=DENSE,
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab=100352,
    activation=SWIGLU,
    norm=LAYERNORM,
    rope_fraction=0.25,
    rope_theta=10_000.0,
)
