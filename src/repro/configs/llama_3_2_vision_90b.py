"""llama-3.2-vision-90b [vlm] — cross-attn image layers.
[hf:meta-llama/Llama-3.2-*-Vision family]

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256. Every 5th layer is
a gated cross-attention layer over vision tokens (100 = 80 self + 20 cross).
The vision frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed patch embeddings (n_image_tokens x d_model).
"""
from .base import SWIGLU, VLM, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family=VLM,
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    activation=SWIGLU,
    cross_attn_every=5,
    n_image_tokens=1601,  # one 560x560 tile -> (560/14)^2 + 1 patches
    rope_theta=500_000.0,
)
