"""qwen3-moe-235b-a22b [moe] — 128 experts top-8. [hf:Qwen/Qwen3 family]

94L d_model=4096 64H (GQA kv=4) expert_d_ff=1536 vocab=151936, MoE 128e top-8.
The largest assigned MoE: EP over the model axis, FSDP over data.
"""
from .base import MOE, SWIGLU, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family=MOE,
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=0,
    vocab=151936,
    activation=SWIGLU,
    n_experts=128,
    top_k=8,
    expert_d_ff=1536,
    rope_theta=1_000_000.0,
)
