"""nemotron-4-15b [dense] — GQA, squared-ReLU MLP. [arXiv:2402.16819]

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000.
Nemotron-4 uses squared-ReLU activations in the MLP (2-matrix MLP) and
rotary position embeddings; no QKV bias.
"""
from .base import DENSE, SQUARED_RELU, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family=DENSE,
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab=256000,
    activation=SQUARED_RELU,
    rope_theta=10_000.0,
)
