"""h2o-danube-3-4b [dense] — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818]

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000. The danube series
adopts mistral-style SWA (window 4096), which also makes the long_500k
decode shape runnable (KV bounded by the window).
"""
from .base import DENSE, SWIGLU, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family=DENSE,
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab=32000,
    activation=SWIGLU,
    sliding_window=4096,
    rope_theta=10_000.0,
)
