"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060]

24L d_model=768 vocab=50280, ssm_state=128, expand=2 (d_inner=1536),
head_dim=64 (24 SSD heads), 1 B/C group, conv width 4. Ties embeddings
(mamba2-130m shares the LM head with the input embedding).
"""
from .base import SSM, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family=SSM,
    n_layers=24,
    d_model=768,
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
)
