"""whisper-medium [audio] — encoder-decoder, conv frontend stubbed.
[arXiv:2212.04356]

24L (encoder) + 24L (decoder) d_model=1024 16H (kv=16 == MHA) d_ff=4096
vocab=51865. GELU MLPs, LayerNorm, learned absolute positions in the
decoder, sinusoidal (here: learned table) positions over 1500 audio frames.
The mel-spectrogram conv frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (n_audio_frames x d_model).
"""
from .base import ENCDEC, GELU, LAYERNORM, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family=ENCDEC,
    n_layers=24,       # decoder layers
    n_enc_layers=24,   # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    activation=GELU,
    norm=LAYERNORM,
    learned_pos=True,
    rope_fraction=0.0,  # whisper uses learned absolute positions, no rotary
    max_position=448,       # whisper decoder context
    n_audio_frames=1500,    # 30 s of audio after conv frontend
)
