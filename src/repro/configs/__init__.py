"""Config registry: ``get_config(arch)`` / ``ARCHS`` / shape specs.

Every assigned architecture is a selectable config (``--arch <id>``); the
reduced smoke variant is derived via ``get_config(arch).reduced()``.
"""
from __future__ import annotations

from typing import Dict

from .base import (
    ALL_SHAPES,
    DENSE,
    ENCDEC,
    HYBRID,
    MOE,
    SHAPES,
    SSM,
    VLM,
    ModelConfig,
    ShapeSpec,
    shape_applicable,
)
from .granite_moe_3b_a800m import CONFIG as _granite
from .h2o_danube_3_4b import CONFIG as _danube
from .llama_3_2_vision_90b import CONFIG as _llama_vision
from .mamba2_130m import CONFIG as _mamba2
from .nemotron_4_15b import CONFIG as _nemotron
from .paper_urdma import FIG3_CLAIMS, PAPER_WORKLOAD, PaperWorkload
from .qwen2_7b import CONFIG as _qwen2
from .qwen3_moe_235b_a22b import CONFIG as _qwen3moe
from .stablelm_1_6b import CONFIG as _stablelm
from .whisper_medium import CONFIG as _whisper
from .zamba2_2_7b import CONFIG as _zamba2

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _nemotron,
        _danube,
        _qwen2,
        _stablelm,
        _granite,
        _qwen3moe,
        _mamba2,
        _llama_vision,
        _whisper,
        _zamba2,
    )
}


def get_config(arch: str) -> ModelConfig:
    """Look up an assigned architecture by id (``--arch <id>``)."""
    if arch not in ARCHS:
        raise KeyError(
            f"unknown arch {arch!r}; available: {', '.join(sorted(ARCHS))}"
        )
    return ARCHS[arch]


def get_shape(name: str) -> ShapeSpec:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {', '.join(SHAPES)}")
    return SHAPES[name]


__all__ = [
    "ARCHS",
    "ALL_SHAPES",
    "SHAPES",
    "ModelConfig",
    "ShapeSpec",
    "shape_applicable",
    "get_config",
    "get_shape",
    "FIG3_CLAIMS",
    "PAPER_WORKLOAD",
    "PaperWorkload",
    "DENSE",
    "MOE",
    "SSM",
    "HYBRID",
    "ENCDEC",
    "VLM",
]
