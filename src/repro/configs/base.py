"""Architecture + run configuration dataclasses.

``ModelConfig`` is the single source of truth for every assigned architecture
(the 10-arch pool) plus the paper's own experiment config. It deliberately
covers all families — dense / MoE / SSM / hybrid / enc-dec / VLM — with one
flat, explicit schema so that launchers, the dry-run, sharding rules, and the
model builders all consume the same object.

Design rules
------------
* Configs are frozen dataclasses: hashable, printable, diffable.
* ``reduced()`` derives the CPU-smoke variant of any config (small widths,
  few layers/experts, tiny vocab) while preserving every structural feature
  (GQA ratio, activation, SWA, MoE top-k, SSM state, hybrid period, ...), so
  smoke tests exercise the same code paths as the full config.
* No behavior lives here — just data. Builders live in ``repro.models``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

# ---------------------------------------------------------------------------
# Families
# ---------------------------------------------------------------------------
DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
ENCDEC = "encdec"   # whisper: encoder-decoder with (stubbed) audio frontend
VLM = "vlm"         # llama-3.2-vision: decoder + cross-attn image layers

FAMILIES = (DENSE, MOE, SSM, HYBRID, ENCDEC, VLM)

# Activation kinds
SWIGLU = "swiglu"            # llama-style gated MLP (3 matrices)
SQUARED_RELU = "squared_relu"  # nemotron-4 (2 matrices, relu(x)**2)
GELU = "gelu"                # whisper / classic transformer (2 matrices)

# Norm kinds
RMSNORM = "rmsnorm"
LAYERNORM = "layernorm"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture. Field groups are family-gated; unused fields are 0/None."""

    name: str
    family: str

    # ---- trunk dimensions (all families) ----
    n_layers: int
    d_model: int
    vocab: int

    # ---- attention (dense/moe/hybrid/encdec/vlm; 0 heads => attention-free) ----
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0              # 0 => d_model // n_heads
    qkv_bias: bool = False         # qwen2
    sliding_window: int = 0        # 0 => full attention; h2o-danube SWA
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0     # stablelm-2: partial rotary (0.25)
    learned_pos: bool = False      # whisper: learned absolute positions
    max_position: int = 0          # learned-pos table size (0 = unused)

    # ---- MLP ----
    d_ff: int = 0
    activation: str = SWIGLU
    norm: str = RMSNORM
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # ---- MoE (family == moe) ----
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0           # per-expert hidden (assignment lists it as d_ff)
    router_aux_coef: float = 0.01  # load-balance auxiliary loss
    capacity_factor: float = 1.25  # staged-dispatch per-expert capacity

    # ---- SSM / Mamba2 (family in {ssm, hybrid}) ----
    ssm_state: int = 0             # N: state dimension per head
    ssm_expand: int = 2            # d_inner = expand * d_model
    ssm_head_dim: int = 64         # P: channels per SSD head
    ssm_groups: int = 1            # G: B/C groups (GVA)
    ssm_conv: int = 4              # depthwise causal conv width
    ssm_chunk: int = 256           # SSD chunk length

    # ---- hybrid (zamba2): shared attention block applied every N ssm layers ----
    hybrid_attn_every: int = 0     # 0 => no shared attention block

    # ---- enc-dec (whisper) ----
    n_enc_layers: int = 0
    n_audio_frames: int = 1500     # stubbed conv frontend output length (30 s)

    # ---- VLM (llama-3.2-vision) ----
    cross_attn_every: int = 0      # every Nth layer is a cross-attn layer
    n_image_tokens: int = 0        # stubbed vision-frontend output tokens

    # ---- numerics ----
    dtype: str = "bfloat16"        # activation/compute dtype
    param_dtype: str = "float32"   # master parameter dtype

    # ------------------------------------------------------------------
    # Derived properties
    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def attention_free(self) -> bool:
        return self.family == SSM

    @property
    def subquadratic(self) -> bool:
        """Can this arch run the long_500k decode shape?

        SSM/hybrid: O(1) state. SWA: KV bounded by window. Full attention
        with a 512k KV cache is skipped (documented in DESIGN.md).
        """
        return self.family in (SSM, HYBRID) or self.sliding_window > 0

    @property
    def has_decoder(self) -> bool:
        return True  # every pool arch decodes (whisper is enc-dec, not enc-only)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count N (for MODEL_FLOPS = 6·N·D roofline)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """N_active: MoE counts only top_k of n_experts expert params."""
        return _param_count(self, active_only=True)

    def reduced(self) -> "ModelConfig":
        """CPU-smoke variant: same structure, tiny sizes."""
        r = dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4 if self.family == HYBRID else 2),
            d_model=64,
            vocab=256,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=0,
            head_dim=16 if self.n_heads else 0,
            d_ff=128 if self.d_ff else 0,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2),
            expert_d_ff=64 if self.expert_d_ff else 0,
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=16,
            sliding_window=min(self.sliding_window, 32),
            hybrid_attn_every=min(self.hybrid_attn_every, 2),
            n_enc_layers=min(self.n_enc_layers, 2),
            n_audio_frames=32 if self.n_enc_layers else self.n_audio_frames,
            cross_attn_every=min(self.cross_attn_every, 2),
            n_image_tokens=16 if self.n_image_tokens else 0,
            max_position=4096 if self.learned_pos else 0,
            dtype="float32",
            param_dtype="float32",
        )
        if r.n_heads:
            # preserve the GQA grouping ratio where possible
            ratio = max(1, self.n_heads // max(self.n_kv_heads, 1))
            object.__setattr__(r, "n_kv_heads", max(1, r.n_heads // min(ratio, r.n_heads)))
        return r


def _attn_params(cfg: ModelConfig) -> int:
    hd = cfg.resolved_head_dim
    q = cfg.d_model * cfg.n_heads * hd
    kv = 2 * cfg.d_model * cfg.n_kv_heads * hd
    o = cfg.n_heads * hd * cfg.d_model
    bias = (cfg.n_heads + 2 * cfg.n_kv_heads) * hd if cfg.qkv_bias else 0
    return q + kv + o + bias


def _mlp_params(cfg: ModelConfig, d_ff: int) -> int:
    if d_ff == 0:
        return 0
    mats = 3 if cfg.activation == SWIGLU else 2
    return mats * cfg.d_model * d_ff


def _ssm_params(cfg: ModelConfig) -> int:
    """Mamba2 block parameter count."""
    d_in = cfg.d_inner
    nh = cfg.ssm_heads
    g = cfg.ssm_groups
    n = cfg.ssm_state
    # in_proj: d_model -> [z(d_in), x(d_in), B(g*n), C(g*n), dt(nh)]
    in_proj = cfg.d_model * (2 * d_in + 2 * g * n + nh)
    conv = cfg.ssm_conv * (d_in + 2 * g * n)  # depthwise conv over x,B,C
    skip = nh * 2 + nh  # A_log, dt_bias, D
    out_proj = d_in * cfg.d_model
    norm = d_in  # gated RMSNorm
    return in_proj + conv + skip + out_proj + norm


def _layer_params(cfg: ModelConfig, layer_kind: str) -> int:
    """Parameter count for one layer of the given kind."""
    d = cfg.d_model
    if layer_kind == "attn+mlp":
        return _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff) + 2 * d
    if layer_kind == "attn+moe":
        experts = cfg.n_experts * 3 * d * cfg.expert_d_ff  # swiglu experts
        router = d * cfg.n_experts
        return _attn_params(cfg) + experts + router + 2 * d
    if layer_kind == "moe_active":
        experts = cfg.top_k * 3 * d * cfg.expert_d_ff
        router = d * cfg.n_experts
        return _attn_params(cfg) + experts + router + 2 * d
    if layer_kind == "ssm":
        return _ssm_params(cfg) + d
    if layer_kind == "cross+mlp":
        return _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff) + 3 * d
    raise ValueError(layer_kind)


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    d = cfg.d_model
    emb = cfg.vocab * d
    head = 0 if cfg.tie_embeddings else cfg.vocab * d
    total = emb + head + d  # + final norm

    if cfg.family in (DENSE,):
        total += cfg.n_layers * _layer_params(cfg, "attn+mlp")
    elif cfg.family == MOE:
        kind = "moe_active" if active_only else "attn+moe"
        total += cfg.n_layers * _layer_params(cfg, kind)
    elif cfg.family == SSM:
        total += cfg.n_layers * _layer_params(cfg, "ssm")
    elif cfg.family == HYBRID:
        total += cfg.n_layers * _layer_params(cfg, "ssm")
        if cfg.hybrid_attn_every:
            # one SHARED attn+mlp block (weights shared across applications)
            total += _layer_params(cfg, "attn+mlp")
    elif cfg.family == ENCDEC:
        total += cfg.n_enc_layers * _layer_params(cfg, "attn+mlp")
        # decoder layers: self-attn + cross-attn + mlp
        total += cfg.n_layers * (
            2 * _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff) + 3 * d
        )
        if cfg.learned_pos:
            total += cfg.max_position * d + cfg.n_audio_frames * d
    elif cfg.family == VLM:
        n_cross = cfg.n_layers // cfg.cross_attn_every if cfg.cross_attn_every else 0
        n_self = cfg.n_layers - n_cross
        total += n_self * _layer_params(cfg, "attn+mlp")
        total += n_cross * _layer_params(cfg, "cross+mlp")
    else:
        raise ValueError(cfg.family)
    return total


# ---------------------------------------------------------------------------
# Input shapes (assigned per-arch shape set)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell.

    ``step``: which program gets lowered —
      train  -> train_step(tokens[b,s], labels[b,s])
      prefill-> prefill_step(tokens[b,s]) building a KV cache
      decode -> serve_step(one new token against a KV cache of seq_len)
    """

    name: str
    seq_len: int
    global_batch: int
    step: str  # train | prefill | decode


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runnable?, reason-if-skipped) for an (arch x shape) cell."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, (
            "long_500k needs sub-quadratic attention; "
            f"{cfg.name} is pure full-attention (skip per DESIGN.md)"
        )
    return True, ""
