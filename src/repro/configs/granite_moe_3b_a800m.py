"""granite-moe-3b-a800m [moe] — 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base family]

32L d_model=1536 24H (GQA kv=8) expert_d_ff=512 vocab=49155, MoE 40e top-8.

NOTE: the assignment line reads "MoE 40e top-8" while its trailing note says
"32 experts"; we implement the explicit spec: 40 experts, top-8 (recorded in
DESIGN.md §Arch-applicability).
"""
from .base import MOE, SWIGLU, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family=MOE,
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=0,
    vocab=49155,
    activation=SWIGLU,
    n_experts=40,
    top_k=8,
    expert_d_ff=512,
    rope_theta=10_000.0,
)
