"""The paper's own experiment configuration (§4 Preliminary Evaluation).

Two servers, NVIDIA ConnectX-5 Ex RNICs back-to-back. 5 million sequential
16 B inlined RDMA writes; each write targets a 4 KB memory region drawn from
a discrete Zipfian distribution with skew 0.5; region count swept 1..2^20.
RTT measured: post write -> observe 32-bit response locally.

These constants drive ``core/simulator.py`` and ``benchmarks/fig3.py``.
Latency calibration constants live in ``core/types.LatencyModel``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class PaperWorkload:
    n_writes: int = 5_000_000        # paper: 5M sequential writes
    write_bytes: int = 16            # 16 B inlined
    region_bytes: int = 4096         # 4 KB regions
    zipf_skew: float = 0.5           # discrete Zipfian, 0.5 skew
    region_counts: Tuple[int, ...] = tuple(4 ** i for i in range(11))  # 1..2^20
    adaptive_top_k: int = 4096       # hint policy: offload top-4096 regions

    # evaluation-scale knobs (the simulator is vectorized; we can subsample
    # the 5M writes without changing the steady-state average)
    sim_writes: int = 200_000
    sim_warmup: int = 20_000


PAPER_WORKLOAD = PaperWorkload()

# Paper Fig. 3 claims we validate against (µs):
FIG3_CLAIMS = {
    "offload_rtt_1_region": 2.6,     # ~2.6 µs with 1 region (all MTT hits)
    "offload_rtt_2e20_regions": 5.1,  # ~5.1 µs at 2^20 regions (mostly misses)
    "unload_rtt_flat": 3.4,          # ~3.4 µs, ~flat across region counts
    "unload_rtt_2e20_regions": 3.5,  # ~3.5 µs at 2^20
    "improvement_at_2e20": 0.31,     # ~31% latency improvement
}
