from .sharding import (
    attention_scheme,
    batch_pspec,
    cache_pspec,
    dp_axes,
    input_shardings,
    param_pspec,
    param_shardings,
    state_shardings,
    with_shardings,
)

__all__ = [
    "attention_scheme",
    "batch_pspec",
    "cache_pspec",
    "dp_axes",
    "input_shardings",
    "param_pspec",
    "param_shardings",
    "state_shardings",
    "with_shardings",
]
