"""Logical sharding rules: parameter/optimizer/batch/cache PartitionSpecs.

Axis roles
----------
  "model"          tensor parallelism (TP): attention heads or head_dim,
                   MLP hidden, experts (EP), vocab — per divisibility.
  "data"           FSDP for parameters + optimizer state, batch data
                   parallelism for activations.
  "pod"            (multi-pod mesh only) pure DP across pods: parameters
                   replicated across pods, gradients all-reduced over
                   ("pod",) in addition to FSDP's reduce-scatter over data.

Divisibility-driven schemes (recorded per arch in DESIGN.md):
* attention: shard heads when Hq%TP==0 and Hkv%TP==0; else shard q-heads and
  REPLICATE kv projections (Megatron GQA style) when Hq%TP==0; else shard
  head_dim (contraction-sharded attention) when Dh%TP==0; else replicate.
* vocab: shard V over model when divisible (TP vocab parallelism: logits +
  loss reductions partition over V), else shard D.
* experts: EP over model when E%TP==0 (qwen3: 128/16), else TP inside the
  expert FFN (granite: 40 experts, d_ff 512 -> shard d_ff... only when
  divisible, else data).
* KV caches at decode: heads over model when Hkv%TP==0, else SEQUENCE over
  model (flash-decode partial-softmax combine, GSPMD-lowered); batch over
  ("pod","data") when divisible; batch==1 (long_500k) shards sequence over
  every available axis.
"""
from __future__ import annotations

from math import prod
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..compat import make_abstract_mesh  # noqa: F401  (re-export: the
# version-agnostic AbstractMesh constructor lives next to the rules that
# consume it — tests and launch code build abstract meshes through here)
from ..configs.base import ModelConfig


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def axis_size(mesh: Mesh, *names: str) -> int:
    return prod(mesh.shape[n] for n in names if n in mesh.axis_names)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _maybe(axes, size, mesh) -> Optional[Any]:
    """axes (str or tuple) if their product divides size, else None."""
    t = (axes,) if isinstance(axes, str) else tuple(axes)
    if all(a in mesh.axis_names for a in t) and size % axis_size(mesh, *t) == 0:
        return axes
    return None


def attention_scheme(cfg: ModelConfig, mesh: Mesh) -> str:
    m = axis_size(mesh, "model")
    if cfg.n_heads == 0:
        return "none"
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    if hq % m == 0 and hkv % m == 0:
        return "heads"
    if hq % m == 0:
        return "qheads_kvrepl"
    if dh % m == 0:
        return "headdim"
    return "replicate"


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------


def param_pspec(cfg: ModelConfig, mesh: Mesh, path: str, shape: Tuple[int, ...]) -> P:
    """PartitionSpec for one parameter leaf, keyed on its tree path."""
    name = path.split("/")[-1]
    parent = path.split("/")[-2] if "/" in path else ""
    scheme = attention_scheme(cfg, mesh)

    # ---- embeddings / head ----
    if name == "tok":
        # LOOKUP table: never shard the vocab dim — a gather over a
        # vocab-sharded table triggers SPMD "involuntary full
        # rematerialization" (replicates the gather operand). D over model
        # keeps the lookup local; the residual stream re-gathers D cheaply.
        v, d = shape
        return P(None, _maybe("model", d, mesh) or _maybe("data", d, mesh))
    if name == "head":
        # OUTPUT projection: TP vocab parallelism (logits + loss reductions
        # partition over V).
        v, d = shape
        vs = _maybe("model", v, mesh)
        if vs:
            return P(vs, _maybe("data", d, mesh))
        return P(None, _maybe("model", d, mesh) or _maybe("data", d, mesh))

    # ---- attention projections ----
    if name in ("wq", "wk", "wv"):
        d, h, k = shape[-3:]
        lead = (None,) * (len(shape) - 3)  # stacked layer dims
        fs = _maybe("data", d, mesh)
        if scheme == "heads" or (scheme == "qheads_kvrepl" and name == "wq"):
            return P(*lead, fs, _maybe("model", h, mesh), None)
        if scheme == "headdim":
            return P(*lead, fs, None, _maybe("model", k, mesh))
        return P(*lead, fs, None, None)
    if name in ("bq", "bk", "bv"):
        h, k = shape[-2:]
        lead = (None,) * (len(shape) - 2)
        if scheme == "heads" or (scheme == "qheads_kvrepl" and name == "bq"):
            return P(*lead, _maybe("model", h, mesh), None)
        if scheme == "headdim":
            return P(*lead, None, _maybe("model", k, mesh))
        return P(*lead, None, None)
    if name == "wo" and parent in ("attn", "self_attn", "cross_attn"):
        h, k, d = shape[-3:]
        lead = (None,) * (len(shape) - 3)
        fs = _maybe("data", d, mesh)
        if scheme in ("heads", "qheads_kvrepl"):
            return P(*lead, _maybe("model", h, mesh), None, fs)
        if scheme == "headdim":
            return P(*lead, None, _maybe("model", k, mesh), fs)
        return P(*lead, None, None, fs)

    # ---- MoE ----
    if name == "router":
        lead = (None,) * (len(shape) - 2)
        return P(*lead, _maybe("data", shape[-2], mesh), None)
    if parent == "moe" and name in ("wi", "wg"):
        e, d, ff = shape[-3:]
        lead = (None,) * (len(shape) - 3)
        ep = _maybe("model", e, mesh)
        if ep:
            return P(*lead, ep, _maybe("data", d, mesh), None)
        return P(*lead, None, _maybe("data", d, mesh), _maybe("model", ff, mesh))
    if parent == "moe" and name == "wo":
        e, ff, d = shape[-3:]
        lead = (None,) * (len(shape) - 3)
        ep = _maybe("model", e, mesh)
        if ep:
            return P(*lead, ep, None, _maybe("data", d, mesh))
        return P(*lead, None, _maybe("model", ff, mesh), _maybe("data", d, mesh))

    # ---- dense MLP ----
    if name in ("wi", "wg"):
        d, ff = shape[-2:]
        lead = (None,) * (len(shape) - 2)
        return P(*lead, _maybe("data", d, mesh), _maybe("model", ff, mesh))
    if name == "wo":
        ff, d = shape[-2:]
        lead = (None,) * (len(shape) - 2)
        return P(*lead, _maybe("model", ff, mesh), _maybe("data", d, mesh))

    # ---- mamba ----
    if name == "in_proj":
        d, k = shape[-2:]
        lead = (None,) * (len(shape) - 2)
        return P(*lead, _maybe("data", d, mesh), None)
    if name == "out_proj":
        k, d = shape[-2:]
        lead = (None,) * (len(shape) - 2)
        return P(*lead, None, _maybe("data", d, mesh))

    # ---- positions (replicated: small or latency-critical) / norms / rest ----
    return P(*((None,) * len(shape)))


def _key_str(p) -> str:
    if hasattr(p, "key"):      # DictKey
        return str(p.key)
    if hasattr(p, "name"):     # GetAttrKey (NamedTuple fields)
        return str(p.name)
    if hasattr(p, "idx"):      # SequenceKey
        return str(p.idx)
    return str(p)


def tree_paths_and_leaves(tree: Any):
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        yield "/".join(_key_str(p) for p in path), leaf


def param_shardings(cfg: ModelConfig, mesh: Mesh, abstract_params: Any) -> Any:
    """NamedSharding pytree matching the (abstract) params."""
    flat = {
        k: NamedSharding(mesh, param_pspec(cfg, mesh, k, v.shape))
        for k, v in tree_paths_and_leaves(abstract_params)
    }
    leaves = [flat[k] for k, _ in tree_paths_and_leaves(abstract_params)]
    treedef = jax.tree_util.tree_structure(abstract_params)
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# batch / cache rules
# ---------------------------------------------------------------------------


def _dp_for_batch(mesh: Mesh, b: int):
    axes = dp_axes(mesh)
    if axes and b % axis_size(mesh, *axes) == 0:
        return axes if len(axes) > 1 else axes[0]
    if "data" in mesh.axis_names and b % axis_size(mesh, "data") == 0:
        return "data"
    return None


def batch_pspec(cfg: ModelConfig, mesh: Mesh, path: str, shape) -> P:
    """Inputs: tokens/labels/media/pos (batch-leading)."""
    dp = _dp_for_batch(mesh, shape[0]) if len(shape) else None
    return P(dp, *((None,) * (len(shape) - 1)))


def cache_pspec(cfg: ModelConfig, mesh: Mesh, path: str, shape) -> P:
    """Decode caches: [L, B, S, H, K] kv, [L, B, H, P, N] ssm, etc."""
    name = path.split("/")[-1]
    m = axis_size(mesh, "model")
    if name in ("k", "v", "cross_k", "cross_v", "ring_k", "ring_v"):
        l, b, s, h, k = shape
        dp = _dp_for_batch(mesh, b)
        if name in ("ring_k", "ring_v"):
            return P(None, dp, None, _maybe("model", h, mesh), None)
        if dp is None:
            # long_500k (B=1): shard the sequence over every available axis
            all_ax = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
            return P(None, None,
                     _maybe(all_ax, s, mesh) or _maybe("model", s, mesh),
                     _maybe("model", h, mesh) if not _maybe(all_ax, s, mesh) else None,
                     None)
        if h % m == 0:
            return P(None, dp, None, "model", None)
        return P(None, dp, _maybe("model", s, mesh), None, None)
    if name == "ssm":
        l, b, h, p_, n = shape
        dp = _dp_for_batch(mesh, b)
        return P(None, dp, _maybe("model", h, mesh), None, None)
    if name == "conv":
        dp = _dp_for_batch(mesh, shape[1])
        return P(None, dp, *((None,) * (len(shape) - 2)))
    if name in ("ring_slot",):
        dp = _dp_for_batch(mesh, shape[0])
        return P(dp, None)
    if name == "ring_fill":
        return P()
    # fallback: batch-leading
    return batch_pspec(cfg, mesh, path, shape)


def input_shardings(cfg: ModelConfig, mesh: Mesh, specs: Any, step: str) -> Any:
    """Attach NamedShardings to the input_specs pytree of a dry-run cell."""

    def one(key, leaf):
        if key.startswith("cache"):
            ps = cache_pspec(cfg, mesh, key, leaf.shape)
        else:
            ps = batch_pspec(cfg, mesh, key, leaf.shape)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, ps))

    flat = [(k, v) for k, v in tree_paths_and_leaves(specs)]
    leaves = [one(k, v) for k, v in flat]
    treedef = jax.tree_util.tree_structure(specs)
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# train-state rules
# ---------------------------------------------------------------------------


def state_shardings(cfg: ModelConfig, mesh: Mesh, abstract_state: Any) -> Any:
    """TrainState: params + opt moments follow param rules; scalars and
    monitor arrays replicate."""

    def one(key, leaf):
        if key.startswith(("params", "opt/mu", "opt/nu")):
            pkey = key.split("/", 1)[1]
            if pkey.startswith(("mu/", "nu/")):
                pkey = pkey.split("/", 1)[1]
            return NamedSharding(mesh, param_pspec(cfg, mesh, pkey, leaf.shape))
        return NamedSharding(mesh, P(*((None,) * len(leaf.shape))))

    flat = [(k, v) for k, v in tree_paths_and_leaves(abstract_state)]
    leaves = [one(k, v) for k, v in flat]
    treedef = jax.tree_util.tree_structure(abstract_state)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def with_shardings(abstract: Any, shardings: Any) -> Any:
    """ShapeDtypeStruct pytree with shardings attached (for .lower)."""
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract, shardings,
    )
