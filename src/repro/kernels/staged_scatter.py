"""staged_scatter — Pallas TPU kernel for the unload-path drain.

The drain moves staged payload rows (appended sequentially into the staging
ring by the unload module) to their final destination rows (KV-cache pages /
expert buffers). This is the TPU-native analogue of the paper's target-CPU
memcpy: the staging buffer is read CONTIGUOUSLY (perfect HBM streaming) and
each row lands in its destination page via a scalar-prefetched index map —
no gather/scatter HLO, no worst-case dense lowering.

TPU adaptation notes (DESIGN.md §2):
* destination row indices arrive via ``PrefetchScalarGridSpec`` so the DMA
  engine knows the target block BEFORE the grid step runs (the RNIC "knows
  the translation" — by construction, not by cache luck);
* payload rows are tiled to (1, BW) VMEM blocks with BW a multiple of 128
  lanes;
* ``input_output_aliases`` updates the destination in place (the drain is
  an update, not a copy of the whole memory);
* the kernel body is an UNCONDITIONAL copy: invalid entries are handled in
  the (jnp) wrapper by redirecting them to duplicate the last valid write —
  identical data to an identical row is deterministic under any grid order,
  so the kernel needs no predication at all.

PRECONDITION (guaranteed by the unload module's conflict-triggered drains):
valid destination rows are unique within one drain batch.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# TPU lane width: last-dim blocks should be multiples of 128.
_LANE = 128


def _drain_kernel(dst_row_ref, staging_ref, dest_in_ref, dest_ref):
    """One grid step: copy staging row ``i`` block ``j`` -> dest row
    dst_row[i] block ``j`` (row selection happens in the index maps)."""
    dest_ref[...] = staging_ref[...].astype(dest_ref.dtype)


def staged_scatter(
    dest: jnp.ndarray,     # [R, W]
    staging: jnp.ndarray,  # [N, W]
    dst_row: jnp.ndarray,  # int32[N]
    valid: jnp.ndarray,    # bool[N]
    *,
    block_w: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Drain staged rows into destination rows. See module docstring."""
    r, w = dest.shape
    n = staging.shape[0]
    bw = min(block_w, w)
    if w % bw:
        raise ValueError(f"W={w} must be divisible by block_w={bw}")

    # ---- sanitize: valid entries first; tail duplicates the last valid ----
    order = jnp.argsort(~valid, stable=True)
    rows_s = dst_row[order]
    stage_s = staging[order]
    valid_s = valid[order]
    nv = jnp.sum(valid.astype(jnp.int32))
    last = jnp.maximum(nv - 1, 0)
    fill_row = jnp.where(nv > 0, rows_s[last], 0)
    fill_data = jnp.where(nv > 0, stage_s[last], dest[0])
    rows_eff = jnp.where(valid_s, rows_s, fill_row).astype(jnp.int32)
    stage_eff = jnp.where(valid_s[:, None], stage_s, fill_data[None, :])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # rows_eff
        grid=(n, w // bw),
        in_specs=[
            pl.BlockSpec((1, bw), lambda i, j, rows: (i, j)),        # staging
            pl.BlockSpec((1, bw), lambda i, j, rows: (rows[i], j)),  # dest (aliased)
        ],
        out_specs=pl.BlockSpec((1, bw), lambda i, j, rows: (rows[i], j)),
    )
    fn = pl.pallas_call(
        _drain_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dest.shape, dest.dtype),
        input_output_aliases={2: 0},  # dest (operand 2, counting prefetch) -> out
        interpret=interpret,
    )
    return fn(rows_eff, stage_eff, dest)
