"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` is the semantic ground truth: tests sweep shapes/dtypes and
assert_allclose the kernel (interpret mode on CPU, compiled on TPU) against
these. They are also the CPU fallback used by ops.py where Pallas interpret
mode would be needlessly slow.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# staged_scatter: the unload-path drain (staging rows -> destination rows)
# ---------------------------------------------------------------------------


def staged_scatter_ref(
    dest: jnp.ndarray,     # [R, W] destination memory (pages/buffers)
    staging: jnp.ndarray,  # [N, W] staging ring payloads (append order)
    dst_row: jnp.ndarray,  # int32[N] destination row per staged entry
    valid: jnp.ndarray,    # bool[N] live entries
) -> jnp.ndarray:
    """PRECONDITION: valid dst_row entries are UNIQUE. The unload module
    guarantees this (a conflicting incoming write forces a drain first,
    see RemoteWriteEngine._conflicts_ring), so a drain batch never holds
    two entries for one destination row."""
    idx = jnp.where(valid, dst_row, dest.shape[0])  # OOB -> dropped
    return dest.at[idx].set(staging.astype(dest.dtype), mode="drop",
                            unique_indices=True)


# ---------------------------------------------------------------------------
# cms: count-min sketch batched update / query
# ---------------------------------------------------------------------------

_CMS_MULTIPLIERS = (0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F)
_CMS_OFFSETS = (0x165667B1, 0xD3A2646D, 0xFD7046C5, 0xB55A4F09)


def cms_hash(ids: jnp.ndarray, row: int, log2_width: int) -> jnp.ndarray:
    x = ids.astype(jnp.uint32)
    a = jnp.uint32(_CMS_MULTIPLIERS[row])
    b = jnp.uint32(_CMS_OFFSETS[row])
    return ((x * a + b) >> jnp.uint32(32 - log2_width)).astype(jnp.int32)


def cms_update_ref(counts: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """counts [depth, width] (width = 2**k), ids int32[n] -> new counts."""
    depth, width = counts.shape
    log2w = width.bit_length() - 1
    for r in range(depth):
        counts = counts.at[r, cms_hash(ids, r, log2w)].add(1)
    return counts


def cms_query_ref(counts: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    depth, width = counts.shape
    log2w = width.bit_length() - 1
    est = counts[0, cms_hash(ids, 0, log2w)]
    for r in range(1, depth):
        est = jnp.minimum(est, counts[r, cms_hash(ids, r, log2w)])
    return est


# ---------------------------------------------------------------------------
# flash_attention: tiled causal (optionally sliding-window) attention
# ---------------------------------------------------------------------------


def flash_attention_ref(
    q: jnp.ndarray,  # [B, Hq, S, D]
    k: jnp.ndarray,  # [B, Hkv, T, D]
    v: jnp.ndarray,  # [B, Hkv, T, D]
    causal: bool = True,
    window: int = 0,
) -> jnp.ndarray:
    b, hq, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    if hkv != hq:
        reps = hq // hkv
        k = jnp.repeat(k, reps, axis=1)
        v = jnp.repeat(v, reps, axis=1)
    logits = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32)
    logits = logits * (d ** -0.5)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos <= qpos + (t - s)  # queries may sit at the end of kv
    if window > 0:
        mask &= kpos > qpos + (t - s) - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bhtd->bhsd", probs, v)


# ---------------------------------------------------------------------------
# flash_decode: one-token attention against a (long) KV cache
# ---------------------------------------------------------------------------


def flash_decode_paged_ref(
    q: jnp.ndarray,        # [B, C, Hq, D]
    pages_k: jnp.ndarray,  # [n_blocks, ps, Hkv, D] physical pool (one layer)
    pages_v: jnp.ndarray,
    blocks: jnp.ndarray,   # int32 [B, P] physical block ids (clamped >= 0)
    view_ok: jnp.ndarray,  # bool [B, C, P*ps]
    ring_k: jnp.ndarray | None = None,   # [B, R, Hkv, D]
    ring_v: jnp.ndarray | None = None,
    ring_ok: jnp.ndarray | None = None,  # bool [B, R]
) -> jnp.ndarray:
    """Oracle for the fused paged+ring decode kernel: gather the per-slot
    view through the page table, append the staging-ring lanes, then the
    exact ``layers._sdpa_once`` op order (fp32 logits -> mask -> softmax ->
    dtype cast -> weighted sum) so the kernel can be held to ulp-level
    fp32 equality (same op order; XLA's shape-dependent GEMM tiling keeps
    the two graphs ~1e-7 apart — DESIGN.md §7)."""
    b, c, hq, d = q.shape
    ps, hkv = pages_k.shape[1], pages_k.shape[2]
    rows = (blocks[:, :, None] * ps
            + jnp.arange(ps, dtype=blocks.dtype)[None, None, :]).reshape(b, -1)
    flat_k = pages_k.reshape(-1, hkv, d)
    flat_v = pages_v.reshape(-1, hkv, d)
    k = flat_k[rows]           # [B, P*ps, Hkv, D]
    v = flat_v[rows]
    mask = view_ok             # [B, C, P*ps]
    if ring_k is not None:
        k = jnp.concatenate([k, ring_k], axis=1)
        v = jnp.concatenate([v, ring_v], axis=1)
        mask = jnp.concatenate(
            [mask, jnp.broadcast_to(ring_ok[:, None, :],
                                    (b, c, ring_ok.shape[1]))], axis=2)
    if hkv != hq:
        reps = hq // hkv
        k = jnp.repeat(k, reps, axis=2)
        v = jnp.repeat(v, reps, axis=2)
    logits = jnp.einsum("bchd,bthd->bhct", q, k).astype(jnp.float32)
    logits = logits * (d ** -0.5)
    logits = jnp.where(mask[:, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhct,bthd->bchd", probs, v)


def flash_decode_ref(
    q: jnp.ndarray,        # [B, Hq, D]
    k: jnp.ndarray,        # [B, T, Hkv, D]
    v: jnp.ndarray,        # [B, T, Hkv, D]
    kv_mask: jnp.ndarray,  # bool [B, T] valid cache slots
) -> jnp.ndarray:
    b, hq, d = q.shape
    hkv = k.shape[2]
    if hkv != hq:
        reps = hq // hkv
        k = jnp.repeat(k, reps, axis=2)
        v = jnp.repeat(v, reps, axis=2)
    logits = jnp.einsum("bhd,bthd->bht", q, k).astype(jnp.float32) * (d ** -0.5)
    logits = jnp.where(kv_mask[:, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bht,bthd->bhd", probs, v)
