"""flash_attention — VMEM-tiled online-softmax attention (prefill hot spot).

Classic FlashAttention adapted to TPU Pallas:

* grid (B, Hq, S/BQ, T/BK) with the KV dimension innermost; the output
  block (and the running max ``m``, denominator ``l``, accumulator ``acc``
  scratch) is revisited across KV steps — VMEM-resident the whole time.
* BQ/BK default to 128 (MXU-native tile edge); all matmuls run through
  ``lax.dot_general`` with ``preferred_element_type=float32`` so bf16
  inputs accumulate in fp32 on the MXU.
* GQA is expressed in the INDEX MAP (kv head = q head // group): no
  repeated-KV materialization in HBM, the same KV block is streamed for
  all heads of a group.
* causal + sliding-window masking by absolute position; masked lanes are
  zeroed in the probability block (not just -inf'd) so fully-masked tiles
  contribute nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, bq, bk, n_kv, causal, window, q_offset, scale,
):
    i = pl.program_id(2)  # query block
    j = pl.program_id(3)  # kv block

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]  # [BQ, D]
    k = k_ref[0, 0]  # [BK, D]
    v = v_ref[0, 0]

    scores = lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [BQ, BK]

    # absolute positions: queries may sit at the end of the kv stream
    qpos = i * bq + lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    kpos = j * bk + lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask, scores, _NEG_INF)

    m_prev = m_ref[...]          # [BQ, 1]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)
    p = jnp.where(mask, p, 0.0)  # fully-masked tiles contribute nothing
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    pv = lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] = alpha * acc_ref[...] + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,  # [B, Hq, S, D]
    k: jnp.ndarray,  # [B, Hkv, T, D]
    v: jnp.ndarray,  # [B, Hkv, T, D]
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    assert hq % hkv == 0, "GQA requires Hq % Hkv == 0"
    group = hq // hkv
    bq, bk = min(block_q, s), min(block_k, t)
    assert s % bq == 0 and t % bk == 0, (s, bq, t, bk)
    n_kv = t // bk
    q_offset = t - s  # queries aligned to the end of the KV stream

    grid = (b, hq, s // bq, n_kv)
    fn = pl.pallas_call(
        functools.partial(
            _attn_kernel,
            bq=bq, bk=bk, n_kv=n_kv, causal=causal, window=window,
            q_offset=q_offset, scale=d ** -0.5,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, i, j: (b_, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, i, j: (b_, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )
    return fn(q, k, v)
