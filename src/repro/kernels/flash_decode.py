"""flash_decode — one-token attention against a long KV cache.

The decode-shape hot spot (decode_32k / long_500k): a single query row per
sequence attends over a 32k–512k-entry KV cache. The kernel streams the
cache in BK-sized blocks, keeping the online-softmax state (m, l, acc) in
VMEM; the cache layout is [B, T, Hkv, D] — the same layout the uRDMA write
engine maintains — so no transpose materializes at decode time.

Under shard_map with the cache sequence-sharded, each device runs this
kernel over its local T-shard and the partial (acc, l, m) triples are
combined with a 3-way psum-style log-sum-exp merge (see ops.flash_decode's
``partial`` mode) — the flash-decode sequence-parallel pattern.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _decode_kernel(
    q_ref, k_ref, v_ref, mask_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, bk, n_kv, scale, group,
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]           # [1, D] single query row (kept 2D for the MXU)
    k = k_ref[0, :, 0]     # [BK, D]
    v = v_ref[0, :, 0]
    valid = mask_ref[0] != 0  # [BK]

    scores = lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [1, BK]
    scores = jnp.where(valid[None, :], scores, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)
    p = jnp.where(valid[None, :], p, 0.0)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    pv = lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] = alpha * acc_ref[...] + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom)[0].astype(o_ref.dtype)


def _paged_kernel(
    # scalar prefetch
    tab_ref,               # int32 [B, P] physical block ids (clamped >= 0)
    # inputs
    q_ref,                 # [1, C, 1, D]
    k_ref,                 # [1, ps, 1, D] one physical KV block
    v_ref,                 # [1, ps, 1, D]
    m_ref,                 # int32 [1, C, ps] view-validity for this block
    *rest,                 # (+ ring refs) then o_ref, then scratch
    ps, n_pages, scale, ring,
):
    """Grid (B, Hq, P): walk the page table for one (slot, head) pair.

    Each step scores one physical block straight out of the pool (the
    BlockSpec below indexes the pool through the scalar-prefetched table —
    no gathered copy ever lands in HBM) and stashes scores/values in VMEM.
    The LAST step appends the staging-ring lanes as a second KV source and
    runs ONE full-width softmax + weighted sum, replicating the jnp
    reference's op ORDER exactly: fused and reference outputs agree to
    fp32 ulp precision (~1e-7 abs) and emit identical greedy tokens. They
    are not bit-identical — XLA tiles the per-page [C, ps] score dots
    differently from the reference's full-width einsum, which is enough to
    reassociate the fp32 sums (see DESIGN.md §7 for the parity contract
    and the online-rescaling trade-off).
    """
    if ring:
        rk_ref, rv_ref, rm_ref, o_ref, s_ref, vb_ref = rest
    else:
        o_ref, s_ref, vb_ref = rest
    j = pl.program_id(2)

    q = q_ref[0, :, 0]       # [C, D]
    k = k_ref[0, :, 0]       # [ps, D]
    v = v_ref[0, :, 0]
    ok = m_ref[0] != 0       # [C, ps]

    s = lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [C, ps]
    s_ref[:, pl.ds(j * ps, ps)] = jnp.where(ok, s, _NEG_INF)
    vb_ref[pl.ds(j * ps, ps), :] = v.astype(vb_ref.dtype)

    @pl.when(j == n_pages - 1)
    def _finalize():
        scores = s_ref[...]      # [C, P*ps] fp32
        vals = vb_ref[...]       # [P*ps, D]
        if ring:
            rk = rk_ref[0, :, 0]        # [R, D]
            rv = rv_ref[0, :, 0]
            rok = rm_ref[0] != 0        # [R]
            sr = lax.dot_general(
                q, rk, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            ) * scale                    # [C, R]
            scores = jnp.concatenate(
                [scores, jnp.where(rok[None, :], sr, _NEG_INF)], axis=1)
            vals = jnp.concatenate([vals, rv.astype(vals.dtype)], axis=0)
        probs = jax.nn.softmax(scores, axis=-1)          # fp32
        out = lax.dot_general(
            probs.astype(vals.dtype), vals, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        o_ref[0, :, 0] = out.astype(o_ref.dtype)


def flash_decode_paged(
    q: jnp.ndarray,         # [B, C, Hq, D] query slab (C=1 for step decode)
    pages_k: jnp.ndarray,   # [n_blocks, ps, Hkv, D] physical pool (one layer)
    pages_v: jnp.ndarray,   # [n_blocks, ps, Hkv, D]
    blocks: jnp.ndarray,    # int32 [B, P] per-slot physical block ids (>= 0)
    view_ok: jnp.ndarray,   # bool [B, C, P*ps] paged-view validity mask
    ring_k: jnp.ndarray | None = None,   # [B, R, Hkv, D] staging-ring lanes
    ring_v: jnp.ndarray | None = None,
    ring_ok: jnp.ndarray | None = None,  # bool [B, R] lane validity
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused paged-attention decode: page-table walk + ring overlay + SDPA.

    The scalar-prefetched ``blocks`` table drives the pool BlockSpecs, so
    each grid step reads its [ps, D] KV tile directly from the physical
    pool; undrained staging-ring lanes join the same softmax as a second
    source. Nothing is gathered or overlaid in HBM first — the read-side
    twin of ``staged_scatter``. Returns [B, C, Hq, D].
    """
    b, c, hq, d = q.shape
    ps, hkv = pages_k.shape[1], pages_k.shape[2]
    n_pages = blocks.shape[1]
    assert hq % hkv == 0
    group = hq // hkv
    assert view_ok.shape == (b, c, n_pages * ps), (view_ok.shape, n_pages, ps)
    ring = ring_k is not None
    if ring:
        r = ring_k.shape[1]
        assert ring_ok is not None and ring_ok.shape == (b, r)

    grid = (b, hq, n_pages)
    in_specs = [
        pl.BlockSpec((1, c, 1, d), lambda b_, h, j, tab: (b_, 0, h, 0)),
        pl.BlockSpec((1, ps, 1, d),
                     lambda b_, h, j, tab: (tab[b_, j], 0, h // group, 0)),
        pl.BlockSpec((1, ps, 1, d),
                     lambda b_, h, j, tab: (tab[b_, j], 0, h // group, 0)),
        pl.BlockSpec((1, c, ps), lambda b_, h, j, tab: (b_, 0, j)),
    ]
    args = [q, pages_k, pages_v, view_ok.astype(jnp.int32)]
    if ring:
        in_specs += [
            pl.BlockSpec((1, r, 1, d),
                         lambda b_, h, j, tab: (b_, 0, h // group, 0)),
            pl.BlockSpec((1, r, 1, d),
                         lambda b_, h, j, tab: (b_, 0, h // group, 0)),
            pl.BlockSpec((1, r), lambda b_, h, j, tab: (b_, 0)),
        ]
        args += [ring_k, ring_v, ring_ok.astype(jnp.int32)]

    fn = pl.pallas_call(
        functools.partial(
            _paged_kernel, ps=ps, n_pages=n_pages, scale=d ** -0.5, ring=ring,
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec(
                (1, c, 1, d), lambda b_, h, j, tab: (b_, 0, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((c, n_pages * ps), jnp.float32),
                pltpu.VMEM((n_pages * ps, d), pages_v.dtype),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )
    return fn(blocks, *args)


def flash_decode(
    q: jnp.ndarray,        # [B, Hq, D]
    k: jnp.ndarray,        # [B, T, Hkv, D]
    v: jnp.ndarray,        # [B, T, Hkv, D]
    kv_mask: jnp.ndarray,  # bool [B, T]
    *,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    group = hq // hkv
    bk = min(block_k, t)
    assert t % bk == 0, (t, bk)
    n_kv = t // bk

    grid = (b, hq, n_kv)
    fn = pl.pallas_call(
        functools.partial(
            _decode_kernel, bk=bk, n_kv=n_kv, scale=d ** -0.5, group=group
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda b_, h, j: (b_, h, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda b_, h, j: (b_, j, h // group, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda b_, h, j: (b_, j, h // group, 0)),
            pl.BlockSpec((1, bk), lambda b_, h, j: (b_, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b_, h, j: (b_, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )
    return fn(q, k, v, kv_mask.astype(jnp.int32))
