"""flash_decode — one-token attention against a long KV cache.

The decode-shape hot spot (decode_32k / long_500k): a single query row per
sequence attends over a 32k–512k-entry KV cache. The kernel streams the
cache in BK-sized blocks, keeping the online-softmax state (m, l, acc) in
VMEM; the cache layout is [B, T, Hkv, D] — the same layout the uRDMA write
engine maintains — so no transpose materializes at decode time.

Under shard_map with the cache sequence-sharded, each device runs this
kernel over its local T-shard and the partial (acc, l, m) triples are
combined with a 3-way psum-style log-sum-exp merge (see ops.flash_decode's
``partial`` mode) — the flash-decode sequence-parallel pattern.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _decode_kernel(
    q_ref, k_ref, v_ref, mask_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, bk, n_kv, scale, group,
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]           # [1, D] single query row (kept 2D for the MXU)
    k = k_ref[0, :, 0]     # [BK, D]
    v = v_ref[0, :, 0]
    valid = mask_ref[0] != 0  # [BK]

    scores = lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [1, BK]
    scores = jnp.where(valid[None, :], scores, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)
    p = jnp.where(valid[None, :], p, 0.0)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    pv = lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] = alpha * acc_ref[...] + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom)[0].astype(o_ref.dtype)


def flash_decode(
    q: jnp.ndarray,        # [B, Hq, D]
    k: jnp.ndarray,        # [B, T, Hkv, D]
    v: jnp.ndarray,        # [B, T, Hkv, D]
    kv_mask: jnp.ndarray,  # bool [B, T]
    *,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    group = hq // hkv
    bk = min(block_k, t)
    assert t % bk == 0, (t, bk)
    n_kv = t // bk

    grid = (b, hq, n_kv)
    fn = pl.pallas_call(
        functools.partial(
            _decode_kernel, bk=bk, n_kv=n_kv, scale=d ** -0.5, group=group
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda b_, h, j: (b_, h, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda b_, h, j: (b_, j, h // group, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda b_, h, j: (b_, j, h // group, 0)),
            pl.BlockSpec((1, bk), lambda b_, h, j: (b_, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b_, h, j: (b_, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )
    return fn(q, k, v, kv_mask.astype(jnp.int32))
