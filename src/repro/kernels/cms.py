"""cms — Pallas TPU kernel for the count-min-sketch monitor hot path.

The paper's decision module must answer "faster than the expected savings"
(§3.2: hundreds of ns per request). The CMS update/query is the only
monitor with an unbounded region universe, so its hot path gets a kernel.

TPU adaptation: instead of serializing scatter-adds (ids can collide), each
grid step materializes the block's hash one-hots with ``broadcasted_iota``
compares and reduces them with a single [B, WIDTH] -> [WIDTH] sum — a
vector-unit friendly histogram that is collision-safe by construction. The
whole sketch (depth x width, e.g. 4 x 4096 int32 = 64 KB) lives in one VMEM
block; ids stream through in blocks of ``block_n``.

Query gathers via the same one-hot trick: est = min_rows (onehot @ counts).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import _CMS_MULTIPLIERS, _CMS_OFFSETS


def _hash_block(ids: jnp.ndarray, row: int, log2_width: int) -> jnp.ndarray:
    x = ids.astype(jnp.uint32)
    a = jnp.uint32(_CMS_MULTIPLIERS[row])
    b = jnp.uint32(_CMS_OFFSETS[row])
    return ((x * a + b) >> jnp.uint32(32 - log2_width)).astype(jnp.int32)


def _update_kernel(ids_ref, counts_ref, out_ref, *, depth, log2_width, block_n):
    """Accumulate one block of ids into the sketch (runs once per block)."""
    width = 1 << log2_width
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = counts_ref[...]

    ids = ids_ref[...]  # [block_n]
    lanes = jax.lax.broadcasted_iota(jnp.int32, (block_n, width), 1)
    for r in range(depth):
        h = _hash_block(ids, r, log2_width)  # [block_n]
        onehot = (lanes == h[:, None]).astype(jnp.int32)
        out_ref[r, :] = out_ref[r, :] + jnp.sum(onehot, axis=0)


def cms_update(
    counts: jnp.ndarray,  # int32[depth, width], width = 2**k
    ids: jnp.ndarray,     # int32[n]
    *,
    block_n: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    depth, width = counts.shape
    log2_width = width.bit_length() - 1
    assert 1 << log2_width == width, "width must be a power of two"
    n = ids.shape[0]
    if n % block_n:
        pad = block_n - n % block_n
        # sentinel ids hash somewhere; mask by appending ids that we then
        # subtract? simpler: pad with the first id and subtract its overcount
        # — instead we require n % block_n == 0 from callers and pad here
        # with a dedicated "ghost" pass handled below.
        ids = jnp.pad(ids, (0, pad), constant_values=ids[0])
        ghost = pad
    else:
        ghost = 0
    nb = ids.shape[0] // block_n

    fn = pl.pallas_call(
        functools.partial(
            _update_kernel, depth=depth, log2_width=log2_width, block_n=block_n
        ),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_n,), lambda j: (j,)),
            pl.BlockSpec((depth, width), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((depth, width), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct(counts.shape, counts.dtype),
        interpret=interpret,
    )
    out = fn(ids, counts)
    if ghost:
        # remove the ghost contributions of the padded copies of ids[0]
        for r in range(depth):
            out = out.at[r, _hash_block(ids[:1], r, log2_width)[0]].add(-ghost)
    return out


def _query_kernel(ids_ref, counts_ref, out_ref, *, depth, log2_width, block_n):
    width = 1 << log2_width
    ids = ids_ref[...]
    lanes = jax.lax.broadcasted_iota(jnp.int32, (block_n, width), 1)
    est = None
    for r in range(depth):
        h = _hash_block(ids, r, log2_width)
        onehot = (lanes == h[:, None]).astype(jnp.int32)
        # gather counts[r, h] as onehot @ counts[r]
        vals = jnp.sum(onehot * counts_ref[r, :][None, :], axis=1)
        est = vals if est is None else jnp.minimum(est, vals)
    out_ref[...] = est


def cms_query(
    counts: jnp.ndarray,
    ids: jnp.ndarray,
    *,
    block_n: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    depth, width = counts.shape
    log2_width = width.bit_length() - 1
    n = ids.shape[0]
    pad = (block_n - n % block_n) % block_n
    if pad:
        ids = jnp.pad(ids, (0, pad))
    nb = ids.shape[0] // block_n

    fn = pl.pallas_call(
        functools.partial(
            _query_kernel, depth=depth, log2_width=log2_width, block_n=block_n
        ),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_n,), lambda j: (j,)),
            pl.BlockSpec((depth, width), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((ids.shape[0],), counts.dtype),
        interpret=interpret,
    )
    out = fn(ids, counts)
    return out[:n]
