"""ops — jit'd public wrappers around the Pallas kernels.

Each wrapper:
* dispatches to the Pallas kernel (compiled on TPU, ``interpret=True`` when
  the backend is CPU — the container validates kernels in interpret mode);
* can be forced to the pure-jnp oracle with ``impl='ref'`` (used by tests
  and as a paranoid fallback);
* is shape/dtype polymorphic within the kernels' documented constraints.
"""
from __future__ import annotations

from functools import partial

import jax

from . import ref
from .cms import cms_query as _cms_query_kernel
from .cms import cms_update as _cms_update_kernel
from .flash_attention import flash_attention as _flash_attention_kernel
from .flash_decode import flash_decode as _flash_decode_kernel
from .flash_decode import flash_decode_paged as _flash_decode_paged_kernel
from .staged_scatter import staged_scatter as _staged_scatter_kernel


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("impl", "block_w"))
def staged_scatter(dest, staging, dst_row, valid, impl: str = "auto", block_w: int = 512):
    """Unload-path drain: move staged rows to destination rows."""
    if impl == "ref":
        return ref.staged_scatter_ref(dest, staging, dst_row, valid)
    bw = block_w
    while dest.shape[1] % bw:
        bw //= 2
    return _staged_scatter_kernel(
        dest, staging, dst_row, valid, block_w=bw, interpret=_on_cpu()
    )


@partial(jax.jit, static_argnames=("impl",))
def cms_update(counts, ids, impl: str = "auto"):
    if impl == "ref":
        return ref.cms_update_ref(counts, ids)
    return _cms_update_kernel(counts, ids, interpret=_on_cpu())


@partial(jax.jit, static_argnames=("impl",))
def cms_query(counts, ids, impl: str = "auto"):
    if impl == "ref":
        return ref.cms_query_ref(counts, ids)
    return _cms_query_kernel(counts, ids, interpret=_on_cpu())


@partial(jax.jit, static_argnames=("causal", "window", "impl", "block_q", "block_k"))
def flash_attention(
    q, k, v, causal: bool = True, window: int = 0,
    impl: str = "auto", block_q: int = 128, block_k: int = 128,
):
    """Tiled attention; q [B,Hq,S,D], kv [B,Hkv,T,D]."""
    if impl == "ref" or (impl == "auto" and _on_cpu()):
        # interpret-mode flash over 32k+ sequences is too slow for CPU
        # smoke/examples; the kernel itself is validated by tests with
        # impl='kernel'.
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    bq = block_q
    while q.shape[2] % bq:
        bq //= 2
    bk = block_k
    while k.shape[2] % bk:
        bk //= 2
    return _flash_attention_kernel(
        q, k, v, causal=causal, window=window,
        block_q=bq, block_k=bk, interpret=_on_cpu(),
    )


@partial(jax.jit, static_argnames=("impl", "block_k"))
def flash_decode(q, k, v, kv_mask, impl: str = "auto", block_k: int = 512):
    """One-token decode attention; q [B,Hq,D], kv [B,T,Hkv,D]."""
    if impl == "ref" or (impl == "auto" and _on_cpu()):
        return ref.flash_decode_ref(q, k, v, kv_mask)
    bk = block_k
    while k.shape[1] % bk:
        bk //= 2
    return _flash_decode_kernel(q, k, v, kv_mask, block_k=bk, interpret=_on_cpu())


@partial(jax.jit, static_argnames=("impl",))
def flash_decode_paged(q, pages_k, pages_v, blocks, view_ok,
                       ring_k=None, ring_v=None, ring_ok=None,
                       impl: str = "auto"):
    """Fused paged decode: page-table walk + staging-ring overlay + SDPA.

    Unlike ``flash_decode``, ``auto`` does NOT silently fall back to the
    oracle on CPU: which implementation serves decode is a negotiated
    engine capability (``core.paths.resolve_attention``), so by the time
    this wrapper runs the caller has already chosen the kernel — on CPU it
    runs in interpret mode (the parity/validation lane).
    """
    if impl == "ref":
        return ref.flash_decode_paged_ref(
            q, pages_k, pages_v, blocks, view_ok, ring_k, ring_v, ring_ok)
    return _flash_decode_paged_kernel(
        q, pages_k, pages_v, blocks, view_ok, ring_k, ring_v, ring_ok,
        interpret=_on_cpu())
