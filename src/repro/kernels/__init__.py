"""Pallas TPU kernels for the compute hot-spots the paper's technique
touches, each with a jit wrapper (ops.py) and a pure-jnp oracle (ref.py):

  staged_scatter   the unload-path drain: staging ring -> destination pages
                   (scalar-prefetched index map, aliased in-place update)
  cms              count-min-sketch monitor update/query (decision hot path)
  flash_attention  VMEM-tiled online-softmax prefill attention (GQA/SWA)
  flash_decode     one-token attention over long KV caches (decode shapes)
  flash_decode_paged  fused paged decode: scalar-prefetched page-table walk
                   + staging-ring overlay + SDPA in one pass (read-side twin
                   of staged_scatter)

Kernels target TPU (BlockSpecs sized for VMEM, 128-lane tiles) and are
validated on CPU with interpret=True against the oracles.
"""
from .ops import (
    cms_query,
    cms_update,
    flash_attention,
    flash_decode,
    flash_decode_paged,
    staged_scatter,
)

__all__ = [
    "cms_query",
    "cms_update",
    "flash_attention",
    "flash_decode",
    "flash_decode_paged",
    "staged_scatter",
]
