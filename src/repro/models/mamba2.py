"""Mamba2 / SSD (state-space duality) blocks. [arXiv:2405.21060]

Implements the chunked SSD algorithm: within a chunk the recurrence is
evaluated as masked (attention-like) matmuls — MXU-friendly; across chunks a
sequential state recurrence carries [B, H, P, N] states. Decode keeps an
O(1) recurrent state + a depthwise-conv tail, which is what makes the
``long_500k`` shape runnable for SSM/hybrid archs.

Shapes: x [B, S, H, P] (P = head channels), dt [B, S, H], A [H],
B/C [B, S, G, N] (G groups broadcast over H heads), state [B, H, P, N].
All decay math in float32.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from . import layers as L

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_mamba_block(cfg: ModelConfig, key: jax.Array) -> Params:
    d, d_in = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_ch = d_in + 2 * g * n
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * g * n + h  # z, x, B, C, dt
    return {
        "ln": L.init_norm(cfg),
        "in_proj": (jax.random.normal(ks[0], (d, proj_out)) * d ** -0.5).astype(
            jnp.float32
        ),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch)) * 0.1).astype(
            jnp.float32
        ),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)
        ),  # A in [-16, -1]
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),  # softplus(-2) ~ 0.12
        "D": jnp.ones((h,), jnp.float32),
        "norm": jnp.ones((d_in,), jnp.float32),  # gated RMSNorm scale
        "out_proj": (jax.random.normal(ks[2], (d_in, d)) * d_in ** -0.5).astype(
            jnp.float32
        ),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    d_in, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z, x, bb, cc, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + g * n, 2 * d_in + 2 * g * n], axis=-1
    )
    return z, x, bb, cc, dt


def _expand_groups(v: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """[..., G, N] -> [..., H, N] broadcast of B/C groups over heads."""
    g = v.shape[-2]
    if g == n_heads:
        return v
    return jnp.repeat(v, n_heads // g, axis=-2)


# ---------------------------------------------------------------------------
# Chunked SSD core
# ---------------------------------------------------------------------------


def segsum(a: jnp.ndarray) -> jnp.ndarray:
    """Cumulative segment sums: out[..., i, j] = sum_{k=j+1..i} a[..., k]
    for i >= j, else -inf. a: [..., Q] -> [..., Q, Q]."""
    q = a.shape[-1]
    x = jnp.broadcast_to(a[..., :, None], a.shape + (q,))  # [..., d(src k), e]
    lower = jnp.tril(jnp.ones((q, q), bool), k=-1)
    x = jnp.where(lower, x, 0.0)
    out = jnp.cumsum(x, axis=-2)
    keep = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(keep, out, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    B: jnp.ndarray,
    C: jnp.ndarray,
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD over a full sequence. Returns (y [B,S,H,P], final_state [B,H,P,N]).

    x [B,S,H,P]; dt [B,S,H] (already softplus'd); A [H] (negative);
    B/C [B,S,G,N].
    """
    b, s, h, p = x.shape
    orig_s = s
    if s % chunk:
        # zero-pad to a chunk multiple: dt==0 makes padded steps identity
        # transitions (decay exp(0)=1, contribution 0), so the final state
        # is exact; padded outputs are sliced off below.
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = x.shape[1]
    nc, q = s // chunk, chunk
    n = B.shape[-1]

    Bh = _expand_groups(B, h)  # [B,S,H,N]
    Ch = _expand_groups(C, h)

    # chunked views
    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h).astype(jnp.float32)
    Bc = Bh.reshape(b, nc, q, h, n)
    Cc = Ch.reshape(b, nc, q, h, n)

    a = dtc * A  # [b,nc,q,h] (negative decays)
    a_hq = jnp.moveaxis(a, -1, -2)  # [b,nc,h,q]
    a_cum = jnp.cumsum(a_hq, axis=-1)  # [b,nc,h,q]

    # keep the data path in the compute dtype (decay math stays f32);
    # mixing them would promote the scan carry to f32 vs the bf16 init
    xdt = xc * dtc[..., None].astype(xc.dtype)  # x * dt

    # 1) intra-chunk (diagonal blocks): masked attention-like matmuls
    Lmat = jnp.exp(segsum(a_hq))  # [b,nc,h,q,q]
    scores = jnp.einsum("bclhn,bcshn->bchls", Cc, Bc)  # [b,nc,h,q,q]
    y_diag = jnp.einsum("bchls,bchls,bcshp->bclhp", scores, Lmat.astype(scores.dtype), xdt)

    # 2) chunk states: decayed sum of inputs within each chunk
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)  # [b,nc,h,q]
    states = jnp.einsum(
        "bcshn,bchs,bcshp->bchpn", Bc, decay_states.astype(x.dtype), xdt
    )  # [b,nc,h,p,n]

    # 3) inter-chunk recurrence (sequential scan over chunks)
    chunk_decay = jnp.exp(a_cum[..., -1])  # [b,nc,h]
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), x.dtype)

    def body(prev, xs):
        st, dec = xs  # [b,h,p,n], [b,h]
        entered = prev  # state entering this chunk
        new = st + dec[..., None, None].astype(st.dtype) * prev
        return new, entered

    states_t = jnp.moveaxis(states, 1, 0)  # [nc,b,h,p,n]
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)  # [nc,b,h]
    final_state, prev_states = lax.scan(body, init_state, (states_t, decay_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # [b,nc,h,p,n]

    # 4) inter-chunk contribution to outputs
    state_decay_out = jnp.exp(a_cum)  # [b,nc,h,q]
    y_off = jnp.einsum(
        "bclhn,bchpn,bchl->bclhp", Cc, prev_states, state_decay_out.astype(x.dtype)
    )

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y[:, :orig_s], final_state


def ssd_decode_step(
    x_t: jnp.ndarray,
    dt_t: jnp.ndarray,
    A: jnp.ndarray,
    B_t: jnp.ndarray,
    C_t: jnp.ndarray,
    state: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One recurrent step. x_t [B,H,P]; dt_t [B,H]; B_t/C_t [B,G,N];
    state [B,H,P,N] -> (y [B,H,P], new_state)."""
    h = x_t.shape[1]
    Bh = _expand_groups(B_t, h)  # [B,H,N]
    Ch = _expand_groups(C_t, h)
    dA = jnp.exp(dt_t.astype(jnp.float32) * A).astype(state.dtype)  # [B,H]
    xdt = x_t * dt_t[..., None].astype(x_t.dtype)
    new_state = state * dA[..., None, None] + jnp.einsum("bhp,bhn->bhpn", xdt, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y, new_state


# ---------------------------------------------------------------------------
# Conv + full block
# ---------------------------------------------------------------------------


def causal_conv(
    xbc: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray,
    left_context: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Depthwise causal conv1d. xbc [B, S, Ch]; w [W, Ch].

    ``left_context`` [B, W-1, Ch]: previous chunk's tail (chunked prefill);
    zeros when starting from scratch."""
    width = w.shape[0]
    if left_context is None:
        pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([left_context.astype(xbc.dtype), xbc], axis=1)
    out = jnp.zeros_like(xbc)
    for i in range(width):  # width is tiny (4): unrolled taps
        out = out + pad[:, i : i + xbc.shape[1]] * w[i].astype(xbc.dtype)
    return jax.nn.silu(out + bias.astype(xbc.dtype))


def conv_decode_step(
    tail: jnp.ndarray, xbc_t: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tail [B, W-1, Ch] (previous inputs), xbc_t [B, Ch] -> (out [B, Ch], new tail)."""
    window = jnp.concatenate([tail, xbc_t[:, None]], axis=1)  # [B, W, Ch]
    out = jnp.einsum("bwc,wc->bc", window, w.astype(xbc_t.dtype))
    out = jax.nn.silu(out + bias.astype(xbc_t.dtype))
    return out, window[:, 1:]


def gated_rmsnorm(y: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray, eps: float):
    """Mamba2 output norm: RMSNorm(y * silu(z)) * scale."""
    g = (y * jax.nn.silu(z)).astype(jnp.float32)
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    return (g * lax.rsqrt(var + eps) * scale).astype(y.dtype)


def mamba_block(
    cfg: ModelConfig, p: Params, u: jnp.ndarray, init_state=None
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full-sequence mamba2 block. u [B, S, D] ->
    (out [B,S,D], final ssm state [B,H,P,N], conv tail [B,W-1,Ch])."""
    dtype = u.dtype
    h, pd, g, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    res = u
    x = L.apply_norm(cfg, p["ln"], u)
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(dtype))
    z, xs, bb, cc, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xs, bb, cc], axis=-1)
    conv_tail = xbc[:, -(cfg.ssm_conv - 1):]  # pre-conv inputs feed decode
    xbc = causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, bb, cc = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + g * n], axis=-1)
    b, s, _ = u.shape
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, final_state = ssd_chunked(
        xs.reshape(b, s, h, pd),
        dt,
        A,
        bb.reshape(b, s, g, n),
        cc.reshape(b, s, g, n),
        cfg.ssm_chunk,
        init_state,
    )
    y = y + p["D"].astype(dtype)[None, None, :, None] * xs.reshape(b, s, h, pd)
    y = y.reshape(b, s, cfg.d_inner)
    y = gated_rmsnorm(y, z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(dtype))
    return res + out, final_state, conv_tail


def mamba_block_chunk(
    cfg: ModelConfig,
    p: Params,
    u: jnp.ndarray,           # [B, C, D] one chunk
    ssm_state: jnp.ndarray,   # [B, H, P, N] state entering the chunk
    conv_tail: jnp.ndarray,   # [B, W-1, Ch] previous chunk's pre-conv tail
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Chunked-prefill mamba block: carries conv + SSD state across chunks."""
    dtype = u.dtype
    h, pd, g, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    res = u
    x = L.apply_norm(cfg, p["ln"], u)
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(dtype))
    z, xs, bb, cc, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xs, bb, cc], axis=-1)
    new_tail = jnp.concatenate([conv_tail.astype(dtype), xbc], axis=1)[
        :, -(cfg.ssm_conv - 1):
    ]
    xbc = causal_conv(xbc, p["conv_w"], p["conv_b"], left_context=conv_tail)
    xs, bb, cc = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + g * n], axis=-1)
    b, s, _ = u.shape
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, final_state = ssd_chunked(
        xs.reshape(b, s, h, pd), dt, A,
        bb.reshape(b, s, g, n), cc.reshape(b, s, g, n),
        cfg.ssm_chunk, init_state=ssm_state,
    )
    y = y + p["D"].astype(dtype)[None, None, :, None] * xs.reshape(b, s, h, pd)
    y = y.reshape(b, s, cfg.d_inner)
    y = gated_rmsnorm(y, z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(dtype))
    return res + out, final_state, new_tail


def mamba_decode_step(
    cfg: ModelConfig,
    p: Params,
    u_t: jnp.ndarray,
    ssm_state: jnp.ndarray,
    conv_tail: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token step. u_t [B, D]; ssm_state [B,H,P,N]; conv_tail [B,W-1,Ch].

    Returns (out [B, D], new ssm_state, new conv_tail)."""
    dtype = u_t.dtype
    h, pd, g, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    res = u_t
    x = L.apply_norm(cfg, p["ln"], u_t[:, None])[:, 0]
    zxbcdt = jnp.einsum("bd,dk->bk", x, p["in_proj"].astype(dtype))
    z, xs, bb, cc, dt = _split_proj(cfg, zxbcdt)
    xbc_t = jnp.concatenate([xs, bb, cc], axis=-1)
    conv_out, conv_tail = conv_decode_step(conv_tail, xbc_t, p["conv_w"], p["conv_b"])
    xs, bb, cc = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + g * n], axis=-1)
    b = u_t.shape[0]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"])
    y, ssm_state = ssd_decode_step(
        xs.reshape(b, h, pd), dt, A, bb.reshape(b, g, n), cc.reshape(b, g, n), ssm_state
    )
    y = y + p["D"].astype(dtype)[None, :, None] * xs.reshape(b, h, pd)
    y = y.reshape(b, cfg.d_inner)
    y = gated_rmsnorm(y, z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bk,kd->bd", y, p["out_proj"].astype(dtype))
    return res + out, ssm_state, conv_tail


def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    """Per-layer recurrent state template (stacked over layers by callers)."""
    conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
    }
