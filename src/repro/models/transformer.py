"""Decoder-only transformer stack (dense + VLM cross-attention variants).

Structure
---------
* Parameters for repeated layers are STACKED along a leading layer axis and
  the stack runs under ``lax.scan`` — HLO size is O(1) in depth, which keeps
  the 40-cell dry-run (and real 1000-node compiles) tractable.
* VLM (llama-3.2-vision style): every ``cross_attn_every``-th layer is a
  gated cross-attention layer over (stub) image embeddings. The scan runs
  over GROUPS of ``cross_attn_every`` layers: (every-1) self layers
  (inner scan) + 1 cross layer.
* The decode path takes a ``kv_writer`` (see ``repro.kvcache``) so KV-cache
  insertion can be routed through the uRDMA write engine (direct scatter =
  offload path, staged ring append + drain = unload path).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kvcache import paged as PG
from ..kvcache import staged as ST
from . import layers as L
from .scan import get_scan

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def init_dense_block(cfg: ModelConfig, key: jax.Array) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_norm(cfg),
        "attn": L.init_attention(cfg, k1),
        "ln2": L.init_norm(cfg),
        "mlp": L.init_mlp(cfg, k2),
    }


def dense_block(
    cfg: ModelConfig,
    p: Params,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    mask: Optional[jnp.ndarray],
) -> jnp.ndarray:
    x = x + L.attention(cfg, p["attn"], L.apply_norm(cfg, p["ln1"], x), positions, mask=mask)
    x = x + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], x))
    return x


def init_cross_block(cfg: ModelConfig, key: jax.Array) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_norm(cfg),
        "attn": L.init_attention(cfg, k1),
        "gate_attn": jnp.zeros((), jnp.float32),
        "ln2": L.init_norm(cfg),
        "mlp": L.init_mlp(cfg, k2),
        "gate_mlp": jnp.zeros((), jnp.float32),
    }


def cross_block(
    cfg: ModelConfig, p: Params, x: jnp.ndarray, media: jnp.ndarray
) -> jnp.ndarray:
    """Gated cross-attention layer (llama-3.2-vision style)."""
    h = L.attention(
        cfg, p["attn"], L.apply_norm(cfg, p["ln1"], x), positions=None,
        kv_x=media, use_rope=False,
    )
    x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * h
    h = L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], x))
    x = x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * h
    return x


def stack_init(init_fn, key: jax.Array, n: int) -> Params:
    """Initialize ``n`` blocks with independent keys, stacked on axis 0."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


# ---------------------------------------------------------------------------
# Decode-time KV handling
# ---------------------------------------------------------------------------


def direct_kv_write(kc, vc, k_new, v_new, slots):
    """Default (offload-path) writer: per-sequence scatter.

    kc/vc: [B, S, Hkv, Dh]; k_new/v_new: [B, 1, Hkv, Dh]; slots: int32 [B].
    Out-of-range slots (>= S) are DROPPED — the adaptive path uses this to
    suppress the main-cache write for staged sequences.
    """
    b = kc.shape[0]
    rows = jnp.arange(b)
    kc = kc.at[rows, slots].set(k_new[:, 0].astype(kc.dtype), mode="drop")
    vc = vc.at[rows, slots].set(v_new[:, 0].astype(vc.dtype), mode="drop")
    return kc, vc


def cache_slots(cfg: ModelConfig, pos: jnp.ndarray, cache_len: int) -> jnp.ndarray:
    """Ring addressing for SWA caches; linear otherwise."""
    if cfg.sliding_window and cache_len <= cfg.sliding_window:
        return (pos % cache_len).astype(jnp.int32)
    return jnp.minimum(pos, cache_len - 1).astype(jnp.int32)


def valid_mask(cfg: ModelConfig, pos: jnp.ndarray, cache_len: int) -> jnp.ndarray:
    """bool [B, S]: which cache slots hold live keys after writing at ``pos``.

    Linear cache: slots 0..pos. SWA ring: all slots once pos >= cache_len-1,
    else slots 0..pos.
    """
    slot_ids = jnp.arange(cache_len)[None, :]
    linear = slot_ids <= pos[:, None]
    if cfg.sliding_window and cache_len <= cfg.sliding_window:
        full = (pos[:, None] >= cache_len - 1)
        return jnp.where(full, True, linear)
    return linear


# ---------------------------------------------------------------------------
# DecoderLM: dense + VLM
# ---------------------------------------------------------------------------


class DecoderLM:
    """Dense decoder-only LM; with ``cfg.cross_attn_every`` also covers VLM."""

    def __init__(self, cfg: ModelConfig, unroll: bool = False):
        self.cfg = cfg
        self._scan = get_scan(unroll)
        self.is_vlm = cfg.cross_attn_every > 0
        if self.is_vlm:
            assert cfg.n_layers % cfg.cross_attn_every == 0
            self.n_groups = cfg.n_layers // cfg.cross_attn_every
            self.n_self_per_group = cfg.cross_attn_every - 1
        else:
            self.n_groups = cfg.n_layers
            self.n_self_per_group = 1

    # -- init ------------------------------------------------------------
    def init(self, key: jax.Array, max_seq: int = 0) -> Params:
        cfg = self.cfg
        k_emb, k_blocks, k_cross = jax.random.split(key, 3)
        params: Params = {"embed": L.init_embed(cfg, k_emb), "ln_f": L.init_norm(cfg)}
        if self.is_vlm:
            n_self = self.n_groups * self.n_self_per_group
            params["blocks"] = stack_init(partial(init_dense_block, cfg), k_blocks, n_self)
            params["cross_blocks"] = stack_init(
                partial(init_cross_block, cfg), k_cross, self.n_groups
            )
        else:
            params["blocks"] = stack_init(
                partial(init_dense_block, cfg), k_blocks, cfg.n_layers
            )
        return params

    # -- full forward (train / prefill) -----------------------------------
    def _trunk(
        self,
        params: Params,
        x: jnp.ndarray,
        positions: jnp.ndarray,
        media: Optional[jnp.ndarray],
        remat: bool,
    ) -> jnp.ndarray:
        cfg = self.cfg
        mask = L.causal_mask(x.shape[1], x.shape[1], cfg.sliding_window)

        def self_body(carry, p):
            return dense_block(cfg, p, carry, positions, mask), None

        if remat:
            self_body = jax.checkpoint(self_body, prevent_cse=False)

        if not self.is_vlm:
            x, _ = self._scan(self_body, x, params["blocks"])
            return x

        nspg = self.n_self_per_group
        grouped = jax.tree.map(
            lambda a: a.reshape((self.n_groups, nspg) + a.shape[1:]), params["blocks"]
        )

        def group_body(carry, ps):
            self_ps, cross_p = ps
            h, _ = self._scan(self_body, carry, self_ps)
            h = cross_block(cfg, cross_p, h, media)
            return h, None

        if remat:
            group_body = jax.checkpoint(group_body, prevent_cse=False)
        x, _ = self._scan(group_body, x, (grouped, params["cross_blocks"]))
        return x

    def forward(
        self,
        params: Params,
        tokens: jnp.ndarray,
        media: Optional[jnp.ndarray] = None,
        remat: bool = False,
    ) -> jnp.ndarray:
        """tokens [B, S] -> logits [B, S, V] (fp32)."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = L.embed_tokens(cfg, params["embed"], tokens, dtype)
        if media is not None:
            media = media.astype(dtype)
        positions = jnp.broadcast_to(
            jnp.arange(tokens.shape[1], dtype=jnp.int32), tokens.shape
        )
        x = self._trunk(params, x, positions, media, remat)
        x = L.apply_norm(cfg, params["ln_f"], x)
        return L.lm_logits(cfg, params["embed"], x)

    def loss(self, params: Params, batch: Dict[str, jnp.ndarray], remat: bool = True):
        logits = self.forward(params, batch["tokens"], batch.get("media"), remat=remat)
        return L.cross_entropy_loss(logits, batch["labels"], batch.get("loss_mask"))

    # -- KV cache ----------------------------------------------------------
    def cache_len(self, max_seq: int) -> int:
        cfg = self.cfg
        if cfg.sliding_window:
            return min(max_seq, cfg.sliding_window)
        return max_seq

    def init_cache(self, batch: int, max_seq: int, dtype=None) -> Params:
        """Abstract-shape-friendly KV cache pytree."""
        cfg = self.cfg
        dims = L.attn_dims(cfg)
        dtype = dtype or jnp.dtype(cfg.dtype)
        s = self.cache_len(max_seq)
        n_layers = (
            self.n_groups * self.n_self_per_group if self.is_vlm else cfg.n_layers
        )
        cache = {
            "k": jnp.zeros((n_layers, batch, s, dims.n_kv_heads, dims.head_dim), dtype),
            "v": jnp.zeros((n_layers, batch, s, dims.n_kv_heads, dims.head_dim), dtype),
        }
        if self.is_vlm:
            cache["cross_k"] = jnp.zeros(
                (self.n_groups, batch, cfg.n_image_tokens, dims.n_kv_heads, dims.head_dim),
                dtype,
            )
            cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
        return cache

    def prefill(
        self,
        params: Params,
        tokens: jnp.ndarray,
        max_seq: int,
        media: Optional[jnp.ndarray] = None,
    ) -> Tuple[jnp.ndarray, Params]:
        """Run the full prompt, build the cache, return last-token logits.

        Dry-run note: prefill writes the whole prompt's KV in one dense slice
        (the offload/direct path — prefill writes are contiguous, exactly the
        case the paper keeps offloaded).
        """
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        b, s = tokens.shape
        x = L.embed_tokens(cfg, params["embed"], tokens, dtype)
        if media is not None:
            media = media.astype(dtype)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        mask = L.causal_mask(s, s, cfg.sliding_window)
        cache = self.init_cache(b, max_seq, dtype)
        clen = self.cache_len(max_seq)

        def keep_ring(k):
            """Last ``clen`` positions, placed at slot = pos % clen."""
            if k.shape[1] < clen:
                pad = [(0, 0), (0, clen - k.shape[1]), (0, 0), (0, 0)]
                return jnp.pad(k, pad)
            tail = k[:, -clen:]
            shift = s % clen
            return jnp.roll(tail, shift, axis=1) if shift else tail

        def self_body(carry, p):
            h = carry
            hn = L.apply_norm(cfg, p["ln1"], h)
            k, v = L.project_kv(cfg, p["attn"], hn, positions)
            h = dense_block(cfg, p, h, positions, mask)
            # keep the last `clen` positions (ring semantics for SWA)
            return h, (keep_ring(k), keep_ring(v))

        if not self.is_vlm:
            x, (ks, vs) = self._scan(self_body, x, params["blocks"])
            cache["k"], cache["v"] = ks, vs
        else:
            nspg = self.n_self_per_group
            grouped = jax.tree.map(
                lambda a: a.reshape((self.n_groups, nspg) + a.shape[1:]),
                params["blocks"],
            )

            def group_body(carry, ps):
                self_ps, cross_p = ps
                h, kv = self._scan(self_body, carry, self_ps)
                ck, cv = L.project_kv(cfg, cross_p["attn"], media, None)
                h = cross_block(cfg, cross_p, h, media)
                return h, (kv, (ck, cv))

            x, (kv, cross_kv) = self._scan(group_body, x, (grouped, params["cross_blocks"]))
            ks, vs = kv
            cache["k"] = ks.reshape((-1,) + ks.shape[2:])
            cache["v"] = vs.reshape((-1,) + vs.shape[2:])
            cache["cross_k"], cache["cross_v"] = cross_kv

        x = L.apply_norm(cfg, params["ln_f"], x[:, -1:])
        logits = L.lm_logits(cfg, params["embed"], x)[:, 0]
        return logits, cache

    # -- chunked prefill -----------------------------------------------------
    def chunk_prefill(
        self,
        params: Params,
        cache: Params,
        tokens: jnp.ndarray,   # [B, C] one chunk
        start_pos: int,        # static: absolute position of tokens[:, 0]
        media: Optional[jnp.ndarray] = None,
    ) -> Tuple[jnp.ndarray, Params]:
        """Chunked prefill: process C prompt tokens against the running
        cache (memory O(C * S) instead of O(S^2) — the prefill_32k path).

        Chunk KV writes are dense slice updates — the offload/direct path;
        the paper (and this engine) only unloads small scattered writes.
        """
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        b, c = tokens.shape
        x = L.embed_tokens(cfg, params["embed"], tokens, dtype)
        if media is not None:
            media = media.astype(dtype)
        positions = jnp.broadcast_to(
            start_pos + jnp.arange(c, dtype=jnp.int32), (b, c)
        )
        clen = cache["k"].shape[2]
        spos = L.slot_positions(clen, start_pos + c - 1)

        def self_body(carry, xs):
            h = carry
            p, kc, vc = xs
            hn = L.apply_norm(cfg, p["ln1"], h)
            k_new, v_new = L.project_kv(cfg, p["attn"], hn, positions)
            kc = L.write_chunk(kc, k_new, start_pos)
            vc = L.write_chunk(vc, v_new, start_pos)
            h = h + L.chunk_attention(cfg, p["attn"], hn, positions, kc, vc, spos)
            h = h + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], h))
            return h, (kc, vc)

        if not self.is_vlm:
            x, (ks, vs) = self._scan(
                self_body, x, (params["blocks"], cache["k"], cache["v"])
            )
            new_cache = dict(cache, k=ks, v=vs)
        else:
            nspg = self.n_self_per_group
            grouped = jax.tree.map(
                lambda a: a.reshape((self.n_groups, nspg) + a.shape[1:]),
                params["blocks"],
            )
            kc_g = cache["k"].reshape((self.n_groups, nspg) + cache["k"].shape[1:])
            vc_g = cache["v"].reshape((self.n_groups, nspg) + cache["v"].shape[1:])

            def group_body(carry, xs):
                self_ps, cross_p, kcs, vcs = xs
                h, kv = self._scan(self_body, carry, (self_ps, kcs, vcs))
                ck, cv = L.project_kv(cfg, cross_p["attn"], media, None)
                h = cross_block(cfg, cross_p, h, media)
                return h, (kv, (ck, cv))

            x, (kv, cross_kv) = self._scan(
                group_body, x, (grouped, params["cross_blocks"], kc_g, vc_g)
            )
            ks, vs = kv
            new_cache = dict(
                cache,
                k=ks.reshape((-1,) + ks.shape[2:]),
                v=vs.reshape((-1,) + vs.shape[2:]),
                cross_k=cross_kv[0],
                cross_v=cross_kv[1],
            )

        x = L.apply_norm(cfg, params["ln_f"], x[:, -1:])
        logits = L.lm_logits(cfg, params["embed"], x)[:, 0]
        return logits, new_cache

    # -- decode (paged pool) -----------------------------------------------
    def decode_step_paged(
        self,
        params: Params,
        cache: Params,
        tokens: jnp.ndarray,
        pos: jnp.ndarray,
        write_mask: jnp.ndarray,
        unload_mask: Optional[jnp.ndarray] = None,
        attention: str = "reference",
        plan: Optional[PG.StepPlan] = None,
    ) -> Tuple[jnp.ndarray, Params]:
        """One decode step against a PAGED KV pool (``repro.kvcache.paged``).

        tokens [B], pos [B] (logical positions, per-slot) -> logits [B, V'],
        new cache. ``write_mask`` [B]: False suppresses every KV write for
        that slot (retired / empty serve slots — their physical destination
        resolves to the drop sentinel, so a dead slot can never touch the
        pool). ``unload_mask`` [B] routes live writes: True = stage into
        the ring overlay (unload path), False/None = direct scatter to the
        slot's physical row (offload path).

        ``attention`` picks the read implementation (negotiate it through
        ``core.paths.resolve_attention``): ``"reference"`` gathers the
        per-slot view from the pool and concatenates the ring in jnp;
        ``"fused"`` hands the physical pool, the scalar-prefetch block
        table, and the ring planes to ``flash_decode_paged``, which walks
        the page table and merges both sources inside one softmax — no
        gathered view ever materializes. The two share one op order and
        agree to fp32 ulp precision with identical greedy tokens (the
        reference is the kernel's oracle; DESIGN.md §7 has the parity
        contract). ``plan`` threads per-segment
        hoisted page-table products (``PG.step_plan``); when None it is
        derived here.

        The per-slot attention view is gathered from the pool through the
        page table each step — values are identical to the dense cache
        layout, so paged decode is bit-compatible with ``decode_step``.
        Linear addressing only: SWA ring addressing and the VLM family
        stay on the dense-lane path (see DESIGN.md §Arch-applicability).
        """
        cfg = self.cfg
        if self.is_vlm or cfg.sliding_window:
            raise NotImplementedError(
                "paged KV decode covers linear-addressed dense caches; "
                "SWA/VLM serve from dense lanes (DESIGN.md §Arch-applicability)"
            )
        fused = attention == "fused"
        dtype = jnp.dtype(cfg.dtype)
        x = L.embed_tokens(cfg, params["embed"], tokens[:, None], dtype)
        ring = PG.has_ring(cache)
        if plan is None:
            plan = PG.step_plan(cache)
        vmask = PG.view_mask_from(plan.allocated, pos)
        view_ids = plan.view_ids
        if ring:
            if unload_mask is None:
                unload_mask = jnp.ones_like(write_mask)
            unload_mask = unload_mask & write_mask
            view_ok, ring_ok, cur = PG.overlay_step_parts(
                cache, vmask, pos, unload_mask)
            full_mask = jnp.concatenate([view_ok, ring_ok], axis=1)
            direct = write_mask & ~unload_mask
        else:
            view_ok = full_mask = vmask
            ring_ok = None
            direct = write_mask
        # physical destination for the direct subset; sentinel (-1 logical
        # -> out-of-range physical) DROPS staged and dead slots
        dest = PG.logical_to_physical(cache, jnp.where(direct, pos, -1))

        def self_body(carry, xs):
            h = carry
            if ring:
                p, pk, pv, rk, rv = xs
            else:
                p, pk, pv = xs
            hn = L.apply_norm(cfg, p["ln1"], h)
            k_new, v_new = L.project_kv(cfg, p["attn"], hn, pos[:, None])
            pk = PG.scatter_token(pk, dest, k_new[:, 0])
            pv = PG.scatter_token(pv, dest, v_new[:, 0])
            if ring:
                rk = PG.stage_tile(rk, k_new[:, 0], cur)
                rv = PG.stage_tile(rv, v_new[:, 0], cur)
            if fused:
                a = L.fused_paged_attention(
                    cfg, p["attn"], hn, pos[:, None], pk, pv,
                    plan.blocks, view_ok[:, None, :],
                    rk if ring else None, rv if ring else None, ring_ok)
            else:
                ak = PG.gather_view(pk, view_ids)
                av = PG.gather_view(pv, view_ids)
                if ring:
                    ak = jnp.concatenate([ak, rk], axis=1)
                    av = jnp.concatenate([av, rv], axis=1)
                a = L.decode_attention(cfg, p["attn"], hn, pos, ak, av,
                                       full_mask)
            h = h + a
            h = h + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], h))
            if ring:
                return h, (pk, pv, rk, rv)
            return h, (pk, pv)

        if ring:
            x, (pks, pvs, rks, rvs) = self._scan(
                self_body, x,
                (params["blocks"], cache["pages_k"], cache["pages_v"],
                 cache["ring_k"], cache["ring_v"]),
            )
            new_cache = PG.ring_commit(
                dict(cache, pages_k=pks, pages_v=pvs, ring_k=rks, ring_v=rvs),
                pos, unload_mask,
            )
        else:
            x, (pks, pvs) = self._scan(
                self_body, x,
                (params["blocks"], cache["pages_k"], cache["pages_v"]),
            )
            new_cache = dict(cache, pages_k=pks, pages_v=pvs)

        x = L.apply_norm(cfg, params["ln_f"], x)
        logits = L.lm_logits(cfg, params["embed"], x)[:, 0]
        return logits, new_cache

    # -- mixed-phase chunk step (paged pool) --------------------------------
    def decode_chunk_paged(
        self,
        params: Params,
        cache: Params,
        tokens: jnp.ndarray,      # int32 [B, C] token slab
        start: jnp.ndarray,       # int32 [B] logical row/position of column 0
        n_valid: jnp.ndarray,     # int32 [B] live columns (chunk len | 1 | 0)
        write_mask: jnp.ndarray,  # bool [B] gates every KV write
        unload_mask: Optional[jnp.ndarray] = None,
        attention: str = "reference",
        plan: Optional[PG.StepPlan] = None,
    ) -> Tuple[jnp.ndarray, Params]:
        """One MIXED-PHASE step against the paged pool: each slot processes
        a [C]-token slab — a prefill chunk (``n_valid`` prompt tokens from
        its chunk cursor), a single decode token (``n_valid == 1``, column
        0), or nothing (``n_valid == 0``, retired/stalled). Column ``j`` of
        slot ``b`` sits at logical row/position ``start[b] + j``.

        Chunk KV writes are dense consecutive rows — the bulk/offload path
        (``unload_mask`` may stage only the scattered column-0 decode
        write). Returns (logits [B, V'] taken at each slot's LAST valid
        column — the sampling position for both phases — and the new
        cache).

        Bit-parity: chunk rows land in the pool before the per-slot view is
        gathered, and every projection/reduction matches the whole-prompt
        ``prefill`` + ``decode_step_paged`` pair, so a prompt prefilled in
        chunks decodes the same token stream as one prefilled whole.
        """
        cfg = self.cfg
        if self.is_vlm or cfg.sliding_window:
            raise NotImplementedError(
                "paged KV decode covers linear-addressed dense caches; "
                "SWA/VLM serve from dense lanes (DESIGN.md §Arch-applicability)"
            )
        fused = attention == "fused"
        dtype = jnp.dtype(cfg.dtype)
        b, c = tokens.shape
        x = L.embed_tokens(cfg, params["embed"], tokens, dtype)
        positions = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
        wvalid = (jnp.arange(c)[None, :] < n_valid[:, None]) & write_mask[:, None]
        ring = PG.has_ring(cache)
        if plan is None:
            plan = PG.step_plan(cache)
        if ring:
            if unload_mask is None:
                unload_mask = jnp.zeros((b,), jnp.bool_)
            unload_mask = unload_mask & wvalid[:, 0]
            view_ok, ring_lane_ok, cur = PG.overlay_chunk_parts(
                cache, positions, unload_mask, allocated=plan.allocated)
            r = ring_lane_ok.shape[1]
            full_mask = jnp.concatenate(
                [view_ok,
                 jnp.broadcast_to(ring_lane_ok[:, None, :], (b, c, r))],
                axis=2)
            direct = wvalid & ~unload_mask[:, None]
        else:
            view_ok = full_mask = PG.view_chunk_mask_from(plan.allocated,
                                                          positions)
            ring_lane_ok = None
            direct = wvalid
        dest = PG.logical_to_physical_many(
            cache, jnp.where(direct, positions, -1))
        view_ids = plan.view_ids

        def self_body(carry, xs):
            h = carry
            if ring:
                p, pk, pv, rk, rv = xs
            else:
                p, pk, pv = xs
            hn = L.apply_norm(cfg, p["ln1"], h)
            k_new, v_new = L.project_kv(cfg, p["attn"], hn, positions)
            pk = PG.scatter_chunk(pk, dest, k_new)
            pv = PG.scatter_chunk(pv, dest, v_new)
            if ring:
                rk = PG.stage_tile(rk, k_new[:, 0], cur)
                rv = PG.stage_tile(rv, v_new[:, 0], cur)
            if fused:
                a = L.fused_paged_attention(
                    cfg, p["attn"], hn, positions, pk, pv,
                    plan.blocks, view_ok,
                    rk if ring else None, rv if ring else None, ring_lane_ok)
            else:
                ak = PG.gather_view(pk, view_ids)
                av = PG.gather_view(pv, view_ids)
                if ring:
                    ak = jnp.concatenate([ak, rk], axis=1)
                    av = jnp.concatenate([av, rv], axis=1)
                a = L.masked_chunk_attention(
                    cfg, p["attn"], hn, positions, ak, av, full_mask)
            h = h + a
            h = h + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], h))
            if ring:
                return h, (pk, pv, rk, rv)
            return h, (pk, pv)

        if ring:
            x, (pks, pvs, rks, rvs) = self._scan(
                self_body, x,
                (params["blocks"], cache["pages_k"], cache["pages_v"],
                 cache["ring_k"], cache["ring_v"]),
            )
            new_cache = PG.ring_commit(
                dict(cache, pages_k=pks, pages_v=pvs, ring_k=rks, ring_v=rvs),
                start, unload_mask,
            )
        else:
            x, (pks, pvs) = self._scan(
                self_body, x,
                (params["blocks"], cache["pages_k"], cache["pages_v"]),
            )
            new_cache = dict(cache, pages_k=pks, pages_v=pvs)

        # logits at each slot's last valid column: the final prompt token
        # (prefill, phase-flip sampling) or the decode token (column 0)
        sel = jnp.clip(n_valid - 1, 0)[:, None, None]
        x = jnp.take_along_axis(x, sel, axis=1)
        x = L.apply_norm(cfg, params["ln_f"], x)
        logits = L.lm_logits(cfg, params["embed"], x)[:, 0]
        return logits, new_cache

    # -- decode ------------------------------------------------------------
    def decode_step(
        self,
        params: Params,
        cache: Params,
        tokens: jnp.ndarray,
        pos: jnp.ndarray,
        kv_writer=direct_kv_write,
        unload_mask: Optional[jnp.ndarray] = None,
    ) -> Tuple[jnp.ndarray, Params]:
        """One decode step. tokens [B], pos [B] -> logits [B, V], new cache.

        KV-write routing (the uRDMA integration):
        * plain cache -> ``kv_writer`` (default: direct scatter = offload
          path);
        * cache with a staging ring (``repro.kvcache.staged.add_ring``) ->
          ``unload_mask`` [B] routes each sequence: True = append to the
          ring (unload path; attention reads cache ∪ ring, the serve loop
          drains in bulk), False = direct scatter. The decision module
          supplies the mask from page-frequency counters.
        """
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        b = tokens.shape[0]
        x = L.embed_tokens(cfg, params["embed"], tokens[:, None], dtype)
        clen = cache["k"].shape[2]
        slots = cache_slots(cfg, pos, clen)
        vmask = valid_mask(cfg, pos, clen)

        has_ring = "ring_k" in cache
        if has_ring and self.is_vlm:
            raise NotImplementedError(
                "staging-ring KV overlay is wired for the dense family; "
                "VLM decode uses the direct path (DESIGN.md §Arch-applicability)"
            )
        if has_ring:
            if unload_mask is None:
                unload_mask = jnp.ones((b,), jnp.bool_)
            # unified-ring overlay bookkeeping: attention mask over
            # cache ∪ ring, direct-subset slots (sentinel drops staged
            # sequences), and the ring column this step appends to
            full_mask, direct_slots, cur = ST.overlay_step(
                cache, vmask, slots, unload_mask
            )
        else:
            full_mask = vmask
            direct_slots = slots

        def self_body(carry, xs):
            h = carry
            if has_ring:
                p, kc, vc, rk, rv = xs
            else:
                p, kc, vc = xs
            hn = L.apply_norm(cfg, p["ln1"], h)
            k_new, v_new = L.project_kv(cfg, p["attn"], hn, pos[:, None])
            if has_ring:
                kc, vc = kv_writer(kc, vc, k_new, v_new, direct_slots)
                rk = ST.stage_tile(rk, k_new, cur)
                rv = ST.stage_tile(rv, v_new, cur)
                ak = jnp.concatenate([kc, rk], axis=1)
                av = jnp.concatenate([vc, rv], axis=1)
                a = L.decode_attention(cfg, p["attn"], hn, pos, ak, av, full_mask)
                h = h + a
                h = h + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], h))
                return h, (kc, vc, rk, rv)
            kc, vc = kv_writer(kc, vc, k_new, v_new, direct_slots)
            a = L.decode_attention(cfg, p["attn"], hn, pos, kc, vc, full_mask)
            h = h + a
            h = h + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], h))
            return h, (kc, vc)

        if has_ring and not self.is_vlm:
            x, (ks, vs, rks, rvs) = self._scan(
                self_body, x,
                (params["blocks"], cache["k"], cache["v"],
                 cache["ring_k"], cache["ring_v"]),
            )
            new_cache = ST.ring_commit(
                dict(cache, k=ks, v=vs, ring_k=rks, ring_v=rvs),
                slots, unload_mask,
            )
        elif not self.is_vlm:
            x, (ks, vs) = self._scan(self_body, x, (params["blocks"], cache["k"], cache["v"]))
            new_cache = dict(cache, k=ks, v=vs)
        else:
            nspg = self.n_self_per_group
            grouped = jax.tree.map(
                lambda a: a.reshape((self.n_groups, nspg) + a.shape[1:]),
                params["blocks"],
            )
            kc_g = cache["k"].reshape((self.n_groups, nspg) + cache["k"].shape[1:])
            vc_g = cache["v"].reshape((self.n_groups, nspg) + cache["v"].shape[1:])

            def group_body(carry, xs):
                self_ps, cross_p, kcs, vcs, ck, cv = xs
                h, kv = self._scan(self_body, carry, (self_ps, kcs, vcs))
                # cross attention against precomputed image KV
                hn = L.apply_norm(cfg, cross_p["ln1"], h)
                a = L.decode_attention(
                    cfg, cross_p["attn"], hn, pos, ck, cv,
                    jnp.ones((b, ck.shape[1]), jnp.bool_), use_rope=False,
                )
                h = h + jnp.tanh(cross_p["gate_attn"]).astype(dtype) * a
                m = L.apply_mlp(cfg, cross_p["mlp"], L.apply_norm(cfg, cross_p["ln2"], h))
                h = h + jnp.tanh(cross_p["gate_mlp"]).astype(dtype) * m
                return h, kv

            x, (ks, vs) = self._scan(
                group_body,
                x,
                (grouped, params["cross_blocks"], kc_g, vc_g,
                 cache["cross_k"], cache["cross_v"]),
            )
            new_cache = dict(
                cache,
                k=ks.reshape((-1,) + ks.shape[2:]),
                v=vs.reshape((-1,) + vs.shape[2:]),
            )

        x = L.apply_norm(cfg, params["ln_f"], x)
        logits = L.lm_logits(cfg, params["embed"], x)[:, 0]
        return logits, new_cache
