"""SSM and hybrid language models.

* ``MambaLM``  — mamba2-130m: pure stack of SSD blocks (attention-free).
* ``ZambaLM``  — zamba2-2.7b: mamba2 trunk with ONE SHARED attention+MLP
  block applied every ``hybrid_attn_every`` layers (zamba2's shared
  transformer block: its weights are reused at every application; each
  application keeps its OWN KV cache at decode time).

Both expose the same API as ``DecoderLM``: init / loss / prefill /
decode_step, with recurrent state (+ per-application KV for zamba) instead
of (or alongside) KV caches — which is what makes ``long_500k`` runnable.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from . import mamba2 as M
from .scan import get_scan
from .transformer import (
    dense_block,
    direct_kv_write,
    init_dense_block,
    stack_init,
    valid_mask,
)

Params = Dict[str, Any]


class MambaLM:
    """Pure SSD stack (mamba2)."""

    def __init__(self, cfg: ModelConfig, unroll: bool = False):
        self.cfg = cfg
        self._scan = get_scan(unroll)

    def init(self, key: jax.Array, max_seq: int = 0) -> Params:
        cfg = self.cfg
        k_emb, k_blocks = jax.random.split(key)
        return {
            "embed": L.init_embed(cfg, k_emb),
            "blocks": stack_init(partial(M.init_mamba_block, cfg), k_blocks, cfg.n_layers),
            "ln_f": L.init_norm(cfg),
        }

    def forward(self, params, tokens, remat: bool = False):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = L.embed_tokens(cfg, params["embed"], tokens, dtype)

        def body(carry, p):
            y, _, _ = M.mamba_block(cfg, p, carry)
            return y, None

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = self._scan(body, x, params["blocks"])
        x = L.apply_norm(cfg, params["ln_f"], x)
        return L.lm_logits(cfg, params["embed"], x)

    def loss(self, params, batch, remat: bool = True):
        logits = self.forward(params, batch["tokens"], remat=remat)
        return L.cross_entropy_loss(logits, batch["labels"], batch.get("loss_mask"))

    # -- recurrent cache --------------------------------------------------
    def init_cache(self, batch: int, max_seq: int = 0, dtype=None) -> Params:
        cfg = self.cfg
        dtype = dtype or jnp.dtype(cfg.dtype)
        per_layer = M.init_mamba_state(cfg, batch, dtype)
        return jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), per_layer
        )

    def prefill(self, params, tokens, max_seq: int = 0, media=None):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = L.embed_tokens(cfg, params["embed"], tokens, dtype)

        def body(carry, p):
            y, st, tail = M.mamba_block(cfg, p, carry)
            return y, (st, tail)

        x, (ssm, conv) = self._scan(body, x, params["blocks"])
        x = L.apply_norm(cfg, params["ln_f"], x[:, -1:])
        logits = L.lm_logits(cfg, params["embed"], x)[:, 0]
        return logits, {"ssm": ssm, "conv": conv}

    def chunk_prefill(self, params, cache, tokens, start_pos: int, media=None):
        """Chunked prefill: run one chunk through the SSD blocks, carrying
        recurrent state in/out (SSM prefill is inherently chunkable)."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = L.embed_tokens(cfg, params["embed"], tokens, dtype)

        def body(carry, xs):
            p, ssm, conv = xs
            y, st, tail = M.mamba_block_chunk(cfg, p, carry, ssm, conv)
            return y, (st, tail)

        x, (ssm, conv) = self._scan(
            body, x, (params["blocks"], cache["ssm"], cache["conv"])
        )
        x = L.apply_norm(cfg, params["ln_f"], x[:, -1:])
        logits = L.lm_logits(cfg, params["embed"], x)[:, 0]
        return logits, {"ssm": ssm, "conv": conv}

    def decode_step(self, params, cache, tokens, pos, kv_writer=None):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = L.embed_tokens(cfg, params["embed"], tokens[:, None], dtype)[:, 0]

        def body(carry, xs):
            p, ssm, conv = xs
            y, ssm, conv = M.mamba_decode_step(cfg, p, carry, ssm, conv)
            return y, (ssm, conv)

        x, (ssm, conv) = self._scan(body, x, (params["blocks"], cache["ssm"], cache["conv"]))
        x = L.apply_norm(cfg, params["ln_f"], x[:, None])
        logits = L.lm_logits(cfg, params["embed"], x)[:, 0]
        return logits, {"ssm": ssm, "conv": conv}


class ZambaLM:
    """Zamba2-style hybrid: mamba2 trunk + shared attention block."""

    def __init__(self, cfg: ModelConfig, unroll: bool = False):
        self.cfg = cfg
        self._scan = get_scan(unroll)
        assert cfg.hybrid_attn_every > 0
        assert cfg.n_layers % cfg.hybrid_attn_every == 0
        self.n_groups = cfg.n_layers // cfg.hybrid_attn_every
        self.per_group = cfg.hybrid_attn_every

    def init(self, key: jax.Array, max_seq: int = 0) -> Params:
        cfg = self.cfg
        k_emb, k_blocks, k_shared = jax.random.split(key, 3)
        return {
            "embed": L.init_embed(cfg, k_emb),
            "blocks": stack_init(partial(M.init_mamba_block, cfg), k_blocks, cfg.n_layers),
            "shared": init_dense_block(cfg, k_shared),  # ONE shared block
            "ln_f": L.init_norm(cfg),
        }

    def _grouped(self, params):
        return jax.tree.map(
            lambda a: a.reshape((self.n_groups, self.per_group) + a.shape[1:]),
            params["blocks"],
        )

    def forward(self, params, tokens, remat: bool = False):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        b, s = tokens.shape
        x = L.embed_tokens(cfg, params["embed"], tokens, dtype)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        mask = L.causal_mask(s, s)
        shared = params["shared"]

        def inner(carry, p):
            y, _, _ = M.mamba_block(cfg, p, carry)
            return y, None

        if remat:
            # checkpoint the inner mamba layers too: a group holds
            # hybrid_attn_every SSD blocks whose in_proj/ssd temps would
            # otherwise all be live during the group's backward pass
            inner = jax.checkpoint(inner, prevent_cse=False)

        def group_body(carry, ps):
            h, _ = self._scan(inner, carry, ps)
            h = dense_block(cfg, shared, h, positions, mask)
            return h, None

        if remat:
            group_body = jax.checkpoint(group_body, prevent_cse=False)
        x, _ = self._scan(group_body, x, self._grouped(params))
        x = L.apply_norm(cfg, params["ln_f"], x)
        return L.lm_logits(cfg, params["embed"], x)

    def loss(self, params, batch, remat: bool = True):
        logits = self.forward(params, batch["tokens"], remat=remat)
        return L.cross_entropy_loss(logits, batch["labels"], batch.get("loss_mask"))

    # -- caches ------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int, dtype=None) -> Params:
        cfg = self.cfg
        dims = L.attn_dims(cfg)
        dtype = dtype or jnp.dtype(cfg.dtype)
        per_layer = M.init_mamba_state(cfg, batch, dtype)
        cache = jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), per_layer
        )
        cache["k"] = jnp.zeros(
            (self.n_groups, batch, max_seq, dims.n_kv_heads, dims.head_dim), dtype
        )
        cache["v"] = jnp.zeros_like(cache["k"])
        return cache

    def prefill(self, params, tokens, max_seq: int, media=None):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        b, s = tokens.shape
        x = L.embed_tokens(cfg, params["embed"], tokens, dtype)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        mask = L.causal_mask(s, s)
        shared = params["shared"]

        def inner(carry, p):
            y, st, tail = M.mamba_block(cfg, p, carry)
            return y, (st, tail)

        def group_body(carry, ps):
            h, states = self._scan(inner, carry, ps)
            hn = L.apply_norm(cfg, shared["ln1"], h)
            k, v = L.project_kv(cfg, shared["attn"], hn, positions)
            h = dense_block(cfg, shared, h, positions, mask)
            return h, (states, (k, v))

        x, ((ssm, conv), (ks, vs)) = self._scan(group_body, x, self._grouped(params))
        # pad prompt KV out to max_seq cache slots
        if s < max_seq:
            pad = [(0, 0), (0, 0), (0, max_seq - s), (0, 0), (0, 0)]
            ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
        cache = {
            "ssm": ssm.reshape((-1,) + ssm.shape[2:]),
            "conv": conv.reshape((-1,) + conv.shape[2:]),
            "k": ks,
            "v": vs,
        }
        x = L.apply_norm(cfg, params["ln_f"], x[:, -1:])
        logits = L.lm_logits(cfg, params["embed"], x)[:, 0]
        return logits, cache

    def chunk_prefill(self, params, cache, tokens, start_pos: int, media=None):
        """Chunked prefill: mamba states carried per layer; the shared
        attention block does chunked attention against its per-application
        KV caches."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        b, c = tokens.shape
        x = L.embed_tokens(cfg, params["embed"], tokens, dtype)
        positions = jnp.broadcast_to(
            start_pos + jnp.arange(c, dtype=jnp.int32), (b, c)
        )
        clen = cache["k"].shape[2]
        spos = L.slot_positions(clen, start_pos + c - 1)
        shared = params["shared"]
        ssm_g = cache["ssm"].reshape(
            (self.n_groups, self.per_group) + cache["ssm"].shape[1:]
        )
        conv_g = cache["conv"].reshape(
            (self.n_groups, self.per_group) + cache["conv"].shape[1:]
        )

        def inner(carry, xs):
            p, ssm, conv = xs
            y, st, tail = M.mamba_block_chunk(cfg, p, carry, ssm, conv)
            return y, (st, tail)

        def group_body(carry, xs):
            ps, ssm, conv, kc, vc = xs
            h, states = self._scan(inner, carry, (ps, ssm, conv))
            hn = L.apply_norm(cfg, shared["ln1"], h)
            k_new, v_new = L.project_kv(cfg, shared["attn"], hn, positions)
            kc = L.write_chunk(kc, k_new, start_pos)
            vc = L.write_chunk(vc, v_new, start_pos)
            h = h + L.chunk_attention(cfg, shared["attn"], hn, positions, kc, vc, spos)
            h = h + L.apply_mlp(cfg, shared["mlp"], L.apply_norm(cfg, shared["ln2"], h))
            return h, (states, (kc, vc))

        x, ((ssm, conv), (ks, vs)) = self._scan(
            group_body, x,
            (self._grouped(params), ssm_g, conv_g, cache["k"], cache["v"]),
        )
        new_cache = {
            "ssm": ssm.reshape((-1,) + ssm.shape[2:]),
            "conv": conv.reshape((-1,) + conv.shape[2:]),
            "k": ks,
            "v": vs,
        }
        x = L.apply_norm(cfg, params["ln_f"], x[:, -1:])
        logits = L.lm_logits(cfg, params["embed"], x)[:, 0]
        return logits, new_cache

    def decode_step(self, params, cache, tokens, pos, kv_writer=direct_kv_write):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = L.embed_tokens(cfg, params["embed"], tokens[:, None], dtype)[:, 0]
        shared = params["shared"]
        clen = cache["k"].shape[2]
        slots = jnp.minimum(pos, clen - 1).astype(jnp.int32)
        vmask = valid_mask(cfg, pos, clen)
        ssm_g = cache["ssm"].reshape((self.n_groups, self.per_group) + cache["ssm"].shape[1:])
        conv_g = cache["conv"].reshape((self.n_groups, self.per_group) + cache["conv"].shape[1:])

        def inner(carry, xs):
            p, ssm, conv = xs
            y, ssm, conv = M.mamba_decode_step(cfg, p, carry, ssm, conv)
            return y, (ssm, conv)

        def group_body(carry, xs):
            ps, ssm, conv, kc, vc = xs
            h, states = self._scan(inner, carry, (ps, ssm, conv))
            hn = L.apply_norm(cfg, shared["ln1"], h[:, None])
            k_new, v_new = L.project_kv(cfg, shared["attn"], hn, pos[:, None])
            kc, vc = kv_writer(kc, vc, k_new, v_new, slots)
            a = L.decode_attention(cfg, shared["attn"], hn, pos, kc, vc, vmask)[:, 0]
            h = h + a
            h2 = L.apply_mlp(cfg, shared["mlp"], L.apply_norm(cfg, shared["ln2"], h[:, None]))
            h = h + h2[:, 0]
            return h, (states, (kc, vc))

        x, ((ssm, conv), (ks, vs)) = self._scan(
            group_body, x, (self._grouped(params), ssm_g, conv_g, cache["k"], cache["v"])
        )
        new_cache = {
            "ssm": ssm.reshape((-1,) + ssm.shape[2:]),
            "conv": conv.reshape((-1,) + conv.shape[2:]),
            "k": ks,
            "v": vs,
        }
        x = L.apply_norm(cfg, params["ln_f"], x[:, None])
        logits = L.lm_logits(cfg, params["embed"], x)[:, 0]
        return logits, new_cache
