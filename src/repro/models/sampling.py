"""Per-request sampling: ``SamplingParams`` and the in-scan slot sampler.

The serving API takes one ``SamplingParams`` per request; the scheduler
loads the fields into fixed-shape per-slot arrays (``SlotState`` carries
them through the jitted scan) and every decode step samples each slot
under ITS OWN parameters — temperature / top-k / top-p / stop set — with
a per-slot PRNG chain. Two requests with different parameters decoding in
one batch are bit-identical to the same requests run sequentially: the
sampler is a pure per-slot function of (logits, key, params).

Equivalence contract (the deprecation-shim tests pin it):

* ``temperature == 0``  -> greedy argmax, exactly the legacy
  ``greedy=True`` engines (argmax never reads the key, so the always-split
  key chain is invisible).
* ``temperature == 1, top_k == 0, top_p == 1`` -> bit-identical to the
  legacy sampled path (``jax.random.categorical`` on unmodified logits:
  ``x / 1.0`` is exact and the disabled filters are ``jnp.where`` no-ops).
* filters compose in the standard order: temperature scale -> top-k mask
  -> top-p (nucleus) mask -> categorical.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

# Fixed width of the per-slot stop-token table inside the scan (padded
# with -1, which no vocabulary token equals). cfg.eos_id takes one entry.
MAX_STOP_TOKENS = 4


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling parameters (the public request knobs).

    temperature   0 = greedy argmax; > 0 = categorical over logits/T.
                  ``None`` defers to the engine default (its legacy
                  ``greedy`` flag: 0.0 when greedy, 1.0 when sampled).
    top_k         keep only the k highest logits (0 = disabled).
    top_p         nucleus sampling: keep the smallest prefix of the sorted
                  distribution with cumulative mass >= top_p (1.0 =
                  disabled).
    max_tokens    generation budget, counting the prefill-emitted token.
    stop_token_ids  emitting any of these retires the request (the
                  engine's ``eos_id`` is always added on top).
    seed          per-request PRNG seed. ``None`` derives the slot key
                  from (engine sample_seed, request id) — the legacy
                  behavior; an explicit seed makes the stream independent
                  of the request id (and so reproducible across queues).
    """

    temperature: Optional[float] = None
    top_k: int = 0
    top_p: float = 1.0
    max_tokens: int = 16
    stop_token_ids: Tuple[int, ...] = ()
    seed: Optional[int] = None

    def __post_init__(self):
        if self.max_tokens < 1:
            raise ValueError("max_tokens must be >= 1 (the prefill token counts)")
        if self.temperature is not None and self.temperature < 0:
            raise ValueError("temperature must be >= 0")
        if not (0.0 < self.top_p <= 1.0):
            raise ValueError("top_p must be in (0, 1]")
        if self.top_k < 0:
            raise ValueError("top_k must be >= 0")
        if len(self.stop_token_ids) > MAX_STOP_TOKENS - 1:
            raise ValueError(
                f"at most {MAX_STOP_TOKENS - 1} stop_token_ids "
                f"(one slot is reserved for the engine eos_id)")


class SlotParams(NamedTuple):
    """``SamplingParams`` resolved into fixed-shape per-slot arrays — the
    form that lives in the scan carry (``SlotState`` embeds these fields).

    temperature: f32[S]; top_k: i32[S]; top_p: f32[S];
    stop: i32[S, MAX_STOP_TOKENS] (-1 padded)
    """

    temperature: jnp.ndarray
    top_k: jnp.ndarray
    top_p: jnp.ndarray
    stop: jnp.ndarray


def make_slot_params(n_slots: int) -> SlotParams:
    return SlotParams(
        temperature=jnp.zeros((n_slots,), jnp.float32),
        top_k=jnp.zeros((n_slots,), jnp.int32),
        top_p=jnp.ones((n_slots,), jnp.float32),
        stop=jnp.full((n_slots, MAX_STOP_TOKENS), -1, jnp.int32),
    )


def stop_table(params: SamplingParams, eos_id: Optional[int]) -> list:
    """The request's -1-padded stop row: stop_token_ids + engine eos_id."""
    ids = list(params.stop_token_ids)
    if eos_id is not None and eos_id not in ids:
        ids.append(int(eos_id))
    if len(ids) > MAX_STOP_TOKENS:
        raise ValueError(f"stop set {ids} exceeds {MAX_STOP_TOKENS} entries")
    return ids + [-1] * (MAX_STOP_TOKENS - len(ids))


# Static sampler variants (the scheduler picks per scan segment from the
# LIVE slots' resolved params, so a pure-greedy workload compiles and
# pays exactly the legacy argmax step):
#   greedy    every live slot has temperature == 0 — argmax, no splits
#   sampled   temperatures only — split + categorical (no vocab sort)
#   filtered  some slot uses top-k / top-p — full mask via one sort
SAMPLE_MODES = ("greedy", "sampled", "filtered")


def _filter_logits(scaled: jnp.ndarray, top_k: jnp.ndarray,
                   top_p: jnp.ndarray) -> jnp.ndarray:
    """One slot's top-k/top-p mask over temperature-scaled logits [V].

    Both filters keep a PREFIX of the descending sort, so they reduce to
    a single logit threshold from ONE sort: rank < top_k, and cumulative
    (post-top-k) mass strictly before the token < top_p. Disabled
    filters are exact no-ops (``jnp.where`` keeps the untouched array),
    so default params reproduce the legacy sampler bit-for-bit.
    """
    v = scaled.shape[-1]
    desc = jnp.sort(scaled)[::-1]
    k_eff = jnp.where((top_k > 0) & (top_k < v), top_k, v)
    in_k = jnp.arange(v) < k_eff
    p_desc = jax.nn.softmax(jnp.where(in_k, desc, -jnp.inf))
    csum = jnp.cumsum(p_desc)
    # ranks beyond k_eff carry p_desc == 0 and csum == 1, so the top-p
    # prefix test also enforces top-k; the rank-0 token always survives
    n_keep = jnp.sum(in_k & ((csum - p_desc) < jnp.minimum(top_p, 1.0)))
    thr = desc[jnp.clip(n_keep - 1, 0, v - 1)]
    enabled = (top_p < 1.0) | ((top_k > 0) & (top_k < v))
    return jnp.where(enabled & (scaled < thr), -jnp.inf, scaled)


def sample_tokens(logits: jnp.ndarray, key_data: jnp.ndarray,
                  params: SlotParams, mode: str = "filtered",
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sample every slot under its own parameters.

    logits f32[S, V], key_data uint32[S, 2] -> (tokens i32[S], new key
    data). ``mode`` is a STATIC specialization hint (``SAMPLE_MODES``);
    it must cover the live slots' params (the scheduler guarantees it)
    and never changes results, only how much work is traced. In the
    sampling modes each slot's key chain splits exactly once per call —
    the same consumption schedule whether the slot's own temperature is
    zero or not, so batch composition never shifts a request's stream
    (greedy slots simply never read their subkey).
    """
    assert mode in SAMPLE_MODES, mode
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if mode == "greedy":
        return greedy, key_data
    pairs = jax.vmap(jax.random.split)(jax.random.wrap_key_data(key_data))
    temp = params.temperature
    scaled = logits / jnp.where(temp > 0, temp, 1.0)[:, None]
    if mode == "filtered":
        scaled = jax.vmap(_filter_logits)(scaled, params.top_k,
                                          params.top_p)
    sampled = jax.vmap(jax.random.categorical)(
        pairs[:, 0], scaled).astype(jnp.int32)
    tokens = jnp.where(temp > 0, sampled, greedy)
    return tokens, jax.random.key_data(pairs[:, 1])


def required_mode(params_list) -> str:
    """The cheapest static sampler variant covering every given
    SamplingParams (resolved, i.e. temperature is a float). Filters only
    matter on slots that actually sample (temperature > 0)."""
    mode = "greedy"
    for p in params_list:
        if p.temperature > 0:
            if p.top_k > 0 or p.top_p < 1.0:
                return "filtered"
            mode = "sampled"
    return mode


def hits_stop(tokens: jnp.ndarray, stop: jnp.ndarray) -> jnp.ndarray:
    """bool[S]: does each slot's emitted token hit its stop set?
    (-1 padding never matches a real token id.)"""
    return jnp.any(tokens[:, None] == stop, axis=1)


def resolve(params: Optional[SamplingParams],
            default: Optional[SamplingParams],
            greedy_default: bool) -> SamplingParams:
    """Resolve a request's effective params. A request's own
    SamplingParams win wholesale; requests without one take the
    engine-wide ``default``. The one per-field backfill is the
    ``None``-marked temperature: request -> engine default's temperature
    -> the legacy ``greedy`` flag (0.0 when greedy, 1.0 when sampled)."""
    p = params if params is not None else (default or SamplingParams())
    if p.temperature is None:
        fallback = (default.temperature
                    if default is not None and default.temperature is not None
                    else None)
        if fallback is None:
            fallback = 0.0 if greedy_default else 1.0
        p = dataclasses.replace(p, temperature=float(fallback))
    return p


def derive_key(base_key: jax.Array, req_id: int,
               seed: Optional[int]) -> jax.Array:
    """The slot PRNG key for one request: an explicit per-request seed
    stands alone (stream independent of queue position / request id);
    otherwise fold the request id into the engine's base key (legacy)."""
    if seed is not None:
        return jax.random.key(int(seed))
    return jax.random.fold_in(base_key, int(req_id))
