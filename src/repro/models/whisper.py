"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment, the mel-spectrogram conv frontend is a STUB:
``input_specs()`` supplies precomputed frame embeddings [B, F, D]. The
transformer backbone is complete: a bidirectional encoder over frames and a
causal decoder with cross-attention, GELU MLPs, LayerNorm, and learned
absolute positions (no rotary).

Decode-time caches: growing self-attention KV (routable through the uRDMA
write engine) + static cross-attention KV precomputed from the encoder.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from .scan import get_scan
from .transformer import direct_kv_write, init_dense_block, stack_init, valid_mask

Params = Dict[str, Any]


def init_decoder_block(cfg: ModelConfig, key: jax.Array) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.init_norm(cfg),
        "self_attn": L.init_attention(cfg, k1),
        "ln2": L.init_norm(cfg),
        "cross_attn": L.init_attention(cfg, k2),
        "ln3": L.init_norm(cfg),
        "mlp": L.init_mlp(cfg, k3),
    }


def decoder_block(
    cfg: ModelConfig,
    p: Params,
    x: jnp.ndarray,
    enc_out: jnp.ndarray,
    mask: jnp.ndarray,
) -> jnp.ndarray:
    x = x + L.attention(
        cfg, p["self_attn"], L.apply_norm(cfg, p["ln1"], x), None,
        mask=mask, use_rope=False,
    )
    x = x + L.attention(
        cfg, p["cross_attn"], L.apply_norm(cfg, p["ln2"], x), None,
        kv_x=enc_out, use_rope=False,
    )
    x = x + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln3"], x))
    return x


class WhisperModel:
    """Enc-dec backbone with the DecoderLM-compatible API."""

    def __init__(self, cfg: ModelConfig, unroll: bool = False):
        self.cfg = cfg
        self._scan = get_scan(unroll)

    def init(self, key: jax.Array, max_seq: int) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 5)
        n_pos = max(cfg.max_position, max_seq)
        return {
            "embed": L.init_embed(cfg, ks[0]),
            "enc_pos": (jax.random.normal(ks[1], (cfg.n_audio_frames, cfg.d_model)) * 0.01
                        ).astype(jnp.float32),
            "dec_pos": (jax.random.normal(ks[2], (n_pos, cfg.d_model)) * 0.01
                        ).astype(jnp.float32),
            "enc_blocks": stack_init(partial(init_dense_block, cfg), ks[3], cfg.n_enc_layers),
            "ln_enc": L.init_norm(cfg),
            "dec_blocks": stack_init(partial(init_decoder_block, cfg), ks[4], cfg.n_layers),
            "ln_f": L.init_norm(cfg),
        }

    # -- encoder -----------------------------------------------------------
    def encode(self, params: Params, frames: jnp.ndarray, remat: bool = False):
        """frames: [B, F, D] stub embeddings -> encoder output [B, F, D]."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = frames.astype(dtype) + params["enc_pos"].astype(dtype)[None, : frames.shape[1]]
        positions = jnp.zeros(frames.shape[:2], jnp.int32)  # unused (no rope)

        def body(carry, p):
            h = carry
            h = h + L.attention(
                cfg, p["attn"], L.apply_norm(cfg, p["ln1"], h), positions,
                mask=None, use_rope=False,
            )
            h = h + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], h))
            return h, None

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = self._scan(body, x, params["enc_blocks"])
        return L.apply_norm(cfg, params["ln_enc"], x)

    # -- decoder full forward ------------------------------------------------
    def forward(self, params, tokens, media, remat: bool = False):
        """tokens [B, S]; media = stub audio frames [B, F, D]."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        enc_out = self.encode(params, media, remat)
        b, s = tokens.shape
        x = L.embed_tokens(cfg, params["embed"], tokens, dtype)
        x = x + params["dec_pos"].astype(dtype)[None, :s]
        mask = L.causal_mask(s, s)

        def body(carry, p):
            return decoder_block(cfg, p, carry, enc_out, mask), None

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = self._scan(body, x, params["dec_blocks"])
        x = L.apply_norm(cfg, params["ln_f"], x)
        return L.lm_logits(cfg, params["embed"], x)

    def loss(self, params, batch, remat: bool = True):
        logits = self.forward(params, batch["tokens"], batch["media"], remat=remat)
        return L.cross_entropy_loss(logits, batch["labels"], batch.get("loss_mask"))

    # -- caches ------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int, dtype=None) -> Params:
        cfg = self.cfg
        dims = L.attn_dims(cfg)
        dtype = dtype or jnp.dtype(cfg.dtype)
        def mk(s):
            return jnp.zeros(
                (cfg.n_layers, batch, s, dims.n_kv_heads, dims.head_dim), dtype)
        return {
            "k": mk(max_seq), "v": mk(max_seq),
            "cross_k": mk(cfg.n_audio_frames), "cross_v": mk(cfg.n_audio_frames),
        }

    def prefill(self, params, tokens, max_seq: int, media=None):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        enc_out = self.encode(params, media)
        b, s = tokens.shape
        x = L.embed_tokens(cfg, params["embed"], tokens, dtype)
        x = x + params["dec_pos"].astype(dtype)[None, :s]
        mask = L.causal_mask(s, s)

        def body(carry, p):
            h = carry
            hn = L.apply_norm(cfg, p["ln1"], h)
            k, v = L.project_kv(cfg, p["self_attn"], hn, None)
            ck, cv = L.project_kv(cfg, p["cross_attn"], enc_out, None)
            h = decoder_block(cfg, p, h, enc_out, mask)
            return h, (k, v, ck, cv)

        x, (ks, vs, cks, cvs) = self._scan(body, x, params["dec_blocks"])
        if s < max_seq:
            pad = [(0, 0), (0, 0), (0, max_seq - s), (0, 0), (0, 0)]
            ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
        cache = {"k": ks, "v": vs, "cross_k": cks, "cross_v": cvs}
        x = L.apply_norm(cfg, params["ln_f"], x[:, -1:])
        logits = L.lm_logits(cfg, params["embed"], x)[:, 0]
        return logits, cache

    def chunk_prefill(self, params, cache, tokens, start_pos: int, media=None):
        """Chunked decoder prefill. If ``media`` is given (first chunk), the
        encoder runs and cross-KV is (re)computed; later chunks reuse it."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        b, c = tokens.shape
        x = L.embed_tokens(cfg, params["embed"], tokens, dtype)
        x = x + jax.lax.dynamic_slice_in_dim(
            params["dec_pos"].astype(dtype), start_pos, c, axis=0
        )[None]
        positions = jnp.broadcast_to(
            start_pos + jnp.arange(c, dtype=jnp.int32), (b, c)
        )
        clen = cache["k"].shape[2]
        spos = L.slot_positions(clen, start_pos + c - 1)
        enc_out = self.encode(params, media) if media is not None else None

        def body(carry, xs):
            h = carry
            p, kc, vc, ck, cv = xs
            hn = L.apply_norm(cfg, p["ln1"], h)
            k_new, v_new = L.project_kv(cfg, p["self_attn"], hn, None)
            kc = L.write_chunk(kc, k_new, start_pos)
            vc = L.write_chunk(vc, v_new, start_pos)
            h = h + L.chunk_attention(
                cfg, p["self_attn"], hn, positions, kc, vc, spos, use_rope=False
            )
            if enc_out is not None:
                ck, cv = L.project_kv(cfg, p["cross_attn"], enc_out, None)
            hn2 = L.apply_norm(cfg, p["ln2"], h)
            h = h + L.chunk_attention(
                cfg, p["cross_attn"], hn2, positions, ck, cv,
                jnp.zeros((cfg.n_audio_frames,), jnp.int32),  # all valid, pos 0
                use_rope=False,
            )
            h = h + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln3"], h))
            return h, (kc, vc, ck, cv)

        x, (ks, vs, cks, cvs) = self._scan(
            body, x,
            (params["dec_blocks"], cache["k"], cache["v"],
             cache["cross_k"], cache["cross_v"]),
        )
        new_cache = {"k": ks, "v": vs, "cross_k": cks, "cross_v": cvs}
        x = L.apply_norm(cfg, params["ln_f"], x[:, -1:])
        logits = L.lm_logits(cfg, params["embed"], x)[:, 0]
        return logits, new_cache

    def decode_step(self, params, cache, tokens, pos, kv_writer=direct_kv_write):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        b = tokens.shape[0]
        x = L.embed_tokens(cfg, params["embed"], tokens[:, None], dtype)
        x = x + jnp.take(params["dec_pos"].astype(dtype), pos, axis=0)[:, None]
        clen = cache["k"].shape[2]
        slots = jnp.minimum(pos, clen - 1).astype(jnp.int32)
        vmask = valid_mask(cfg, pos, clen)
        cross_mask = jnp.ones((b, cfg.n_audio_frames), jnp.bool_)

        def body(carry, xs):
            h = carry
            p, kc, vc, ck, cv = xs
            hn = L.apply_norm(cfg, p["ln1"], h)
            k_new, v_new = L.project_kv(cfg, p["self_attn"], hn, None)
            kc, vc = kv_writer(kc, vc, k_new, v_new, slots)
            h = h + L.decode_attention(cfg, p["self_attn"], hn, pos, kc, vc, vmask,
                                       use_rope=False)
            hn2 = L.apply_norm(cfg, p["ln2"], h)
            h = h + L.decode_attention(cfg, p["cross_attn"], hn2, pos, ck, cv,
                                       cross_mask, use_rope=False)
            h = h + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln3"], h))
            return h, (kc, vc)

        x, (ks, vs) = self._scan(
            body, x,
            (params["dec_blocks"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"]),
        )
        new_cache = dict(cache, k=ks, v=vs)
        x = L.apply_norm(cfg, params["ln_f"], x)
        logits = L.lm_logits(cfg, params["embed"], x)[:, 0]
        return logits, new_cache
