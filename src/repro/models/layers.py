"""Shared model building blocks: norms, rotary, attention (GQA / SWA /
QKV-bias / cross), and MLP variants (swiglu / squared-relu / gelu).

Everything is a pure function over explicit parameter pytrees (nested dicts
of jnp arrays) so stacks compose under ``lax.scan`` and shard under pjit.

Conventions
-----------
* Activations: [B, S, D] (batch, sequence, model).
* Attention heads: q [B, S, Hq, Dh]; kv [B, S, Hkv, Dh] (GQA: Hq % Hkv == 0).
* Softmax and norms accumulate in float32 regardless of compute dtype.
* Init functions take a PRNG key and return the parameter dict; shapes only
  depend on the config so ``jax.eval_shape`` can derive abstract params for
  the dry-run without allocating.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..compat import get_abstract_mesh
from ..configs.base import GELU, LAYERNORM, RMSNORM, SQUARED_RELU, SWIGLU, ModelConfig

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, d: Optional[int] = None) -> dict:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == LAYERNORM:
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == RMSNORM:
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"]
    elif cfg.norm == LAYERNORM:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"] + p["bias"]
    else:
        raise ValueError(cfg.norm)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (with partial-rotary support)
# ---------------------------------------------------------------------------


def rope_frequencies(cfg: ModelConfig, head_dim: int) -> jnp.ndarray:
    """inv_freq [rot_half] for the rotated fraction of the head dim."""
    rot = int(head_dim * cfg.rope_fraction)
    rot -= rot % 2
    if rot == 0:
        return jnp.zeros((0,), jnp.float32)
    exponent = jnp.arange(0, rot, 2, dtype=jnp.float32) / rot
    return 1.0 / (cfg.rope_theta ** exponent)


def apply_rope(
    cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray
) -> jnp.ndarray:
    """Rotate the first ``rope_fraction`` of the head dim.

    x: [B, S, H, Dh]; positions: [B, S] absolute token positions (int32).
    """
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(cfg, head_dim)
    rot = 2 * inv_freq.shape[0]
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    # angles: [B, S, rot/2]
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = x_rot[..., ::2], x_rot[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([rotated, x_pass], axis=-1) if x_pass.shape[-1] else rotated


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_heads: int
    n_kv_heads: int
    head_dim: int


def attn_dims(cfg: ModelConfig) -> AttnDims:
    return AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim)


def init_attention(cfg: ModelConfig, key: jax.Array) -> dict:
    """GQA attention parameters. Shapes keep the head axis explicit so the
    sharding rules can target heads or head_dim depending on divisibility."""
    dims = attn_dims(cfg)
    d, hq, hkv, hd = cfg.d_model, dims.n_heads, dims.n_kv_heads, dims.head_dim
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, hq, hd)) * scale).astype(jnp.float32),
        "wk": (jax.random.normal(ks[1], (d, hkv, hd)) * scale).astype(jnp.float32),
        "wv": (jax.random.normal(ks[2], (d, hkv, hd)) * scale).astype(jnp.float32),
        "wo": (jax.random.normal(ks[3], (hq, hd, d)) * (hq * hd) ** -0.5).astype(
            jnp.float32
        ),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq, hd), jnp.float32)
        p["bk"] = jnp.zeros((hkv, hd), jnp.float32)
        p["bv"] = jnp.zeros((hkv, hd), jnp.float32)
    return p


def _qkv(cfg: ModelConfig, p: dict, x: jnp.ndarray, kv_x: Optional[jnp.ndarray] = None):
    """Project to q, k, v. ``kv_x`` (if given) is the cross-attention source."""
    dtype = x.dtype
    kv_src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
    k = jnp.einsum("btd,dhk->bthk", kv_src, p["wk"].astype(dtype))
    v = jnp.einsum("btd,dhk->bthk", kv_src, p["wv"].astype(dtype))
    if "bq" in p:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    return q, k, v


def repeat_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """Expand [B, T, Hkv, Dh] -> [B, T, Hq, Dh] for GQA."""
    hkv = k.shape[2]
    if hkv == n_heads:
        return k
    reps = n_heads // hkv
    return jnp.repeat(k, reps, axis=2)


# above this many score elements per batch entry, sdpa processes queries in
# blocks so the fp32 score tensor never materializes at [S, T] (the XLA
# fallback for the TPU flash_attention kernel; same math, bounded temps)
_SDPA_BLOCK_THRESHOLD = 4096 * 2048
_SDPA_QBLOCK = 1024


def _tp_head_pad(h: int) -> int:
    """Padded head count for tensor parallelism (0 = no padding needed).

    When the head count does not divide the "model" axis (qwen2: 28H,
    granite: 24H over TP=16), attention pads heads to the next multiple
    with ZERO q/k/v rows — Megatron-style TP padding, applied to the
    ACTIVATIONS only (params keep the paper-exact head count; padded head
    outputs are sliced off, so the math is exact). Costs h_pad/h extra
    attention FLOPs; buys head-sharded score tensors.
    """
    mesh = get_abstract_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return 0
    m = mesh.shape["model"]
    if h % m == 0:
        return 0
    return (h + m - 1) // m * m


def _shard_heads(x: jnp.ndarray) -> jnp.ndarray:
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(x, P(None, None, "model", None))


def _sdpa_once(q, k, v, mask, scale):
    logits = jnp.einsum("bshk,bthk->bhst", q, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, jnp.float32(-1e30))
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthk->bshk", probs, v)


def sdpa(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    mask: Optional[jnp.ndarray],
) -> jnp.ndarray:
    """Scaled dot-product attention, fp32 softmax.

    q: [B, S, H, Dh]; k/v: [B, T, H, Dh]; mask: broadcastable to [B, H, S, T]
    (True = attend). Returns [B, S, H, Dh].

    Long sequences run BLOCKED over queries (exact per-block softmax — the
    full key set is present, so no online rescaling is needed): temp memory
    is O(BQ * T) instead of O(S * T). On TPU the Pallas flash kernel
    replaces this path; the blocked form is the roofline-accountable XLA
    fallback with the same asymptotics in HBM traffic.
    """
    scale = q.shape[-1] ** -0.5
    s, t = q.shape[1], k.shape[1]

    # TP head padding (see _tp_head_pad): keeps score tensors head-sharded
    # for architectures whose head count doesn't divide the model axis.
    h = q.shape[2]
    hp = _tp_head_pad(h)
    if hp:
        pad = [(0, 0), (0, 0), (0, hp - h), (0, 0)]
        q = _shard_heads(jnp.pad(q, pad))
        k = _shard_heads(jnp.pad(k, pad))
        v = _shard_heads(jnp.pad(v, pad))

    if s * t <= _SDPA_BLOCK_THRESHOLD or s <= _SDPA_QBLOCK or s % _SDPA_QBLOCK:
        out = _sdpa_once(q, k, v, mask, scale)
        return out[:, :, :h] if hp else out
    outs = []
    for i in range(0, s, _SDPA_QBLOCK):
        qb = q[:, i : i + _SDPA_QBLOCK]
        mb = None
        if mask is not None:
            mb = mask[:, :, i : i + _SDPA_QBLOCK] if mask.ndim == 4 else mask
        outs.append(_sdpa_once(qb, k, v, mb, scale))
    out = jnp.concatenate(outs, axis=1)
    return out[:, :, :h] if hp else out


def causal_mask(s: int, t: int, window: int = 0, offset: int = 0) -> jnp.ndarray:
    """[1, 1, s, t] causal (optionally sliding-window) mask.

    ``offset``: absolute position of query row 0 minus key col 0 (for
    decode / chunked prefill where queries start mid-sequence).
    """
    qpos = jnp.arange(s)[:, None] + offset
    kpos = jnp.arange(t)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m[None, None]


def attention(
    cfg: ModelConfig,
    p: dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    kv_x: Optional[jnp.ndarray] = None,
    mask: Optional[jnp.ndarray] = None,
    use_rope: bool = True,
) -> jnp.ndarray:
    """Full (training / prefill) attention. Causal unless ``kv_x`` given."""
    dims = attn_dims(cfg)
    q, k, v = _qkv(cfg, p, x, kv_x)
    if use_rope and kv_x is None:
        q = apply_rope(cfg, q, positions)
        k = apply_rope(cfg, k, positions)
    k = repeat_kv(k, dims.n_heads)
    v = repeat_kv(v, dims.n_heads)
    if mask is None and kv_x is None:
        mask = causal_mask(x.shape[1], k.shape[1], cfg.sliding_window)
    out = sdpa(q, k, v, mask)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def project_q(
    cfg: ModelConfig,
    p: dict,
    x: jnp.ndarray,          # [B, S, D]
    positions: Optional[jnp.ndarray],  # [B, S] absolute query positions
    use_rope: bool = True,
) -> jnp.ndarray:
    """Query projection (+ bias + RoPE) for decode-time attention.

    THE one q path shared by the jnp attention cores below and the fused
    ``flash_decode_paged`` read kernel — both implementations consume
    bit-identical queries, so fused-vs-reference parity reduces to the
    attention core itself.
    """
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
    if "bq" in p:
        q = q + p["bq"].astype(dtype)
    if use_rope:
        q = apply_rope(cfg, q, positions)
    return q


def decode_attention(
    cfg: ModelConfig,
    p: dict,
    x: jnp.ndarray,
    pos: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    kv_len_mask: jnp.ndarray,
    use_rope: bool = True,
) -> jnp.ndarray:
    """One-token decode against a (possibly sequence-sharded) KV cache.

    x: [B, 1, D]; pos: [B] absolute positions of the new token;
    k_cache/v_cache: [B, S, Hkv, Dh] — already contain the new token's kv;
    kv_len_mask: bool [B, S] marking valid cache slots (handles both linear
    fill and SWA ring occupancy).

    The softmax reduction runs over the cache's sequence axis; under pjit
    with the cache sequence-sharded over "model", GSPMD partitions the
    max/sum reductions into the flash-decode partial-softmax + combine
    pattern automatically.
    """
    dims = attn_dims(cfg)
    dtype = x.dtype
    q = project_q(cfg, p, x, pos[:, None], use_rope)
    k = repeat_kv(k_cache, dims.n_heads)
    v = repeat_kv(v_cache, dims.n_heads)
    mask = kv_len_mask[:, None, None, :]  # [B, 1, 1, S]
    out = sdpa(q, k, v, mask)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))


def masked_chunk_attention(
    cfg: ModelConfig,
    p: dict,
    x: jnp.ndarray,          # [B, C, D] normed chunk activations
    positions: jnp.ndarray,  # [B, C] absolute query positions
    k_cache: jnp.ndarray,    # [B, T, Hkv, Dh] gathered KV set
    v_cache: jnp.ndarray,
    mask: jnp.ndarray,       # bool [B, C, T] explicit validity (True=attend)
    use_rope: bool = True,
) -> jnp.ndarray:
    """Chunk queries against a gathered KV set with an EXPLICIT mask.

    The mixed-phase serving step attends per-slot chunk windows over the
    paged pool view (∪ staging ring), whose validity depends on page-table
    allocation and ring shadowing — structure the caller owns. With C=1
    and ``mask = kv_len_mask[:, None, :]`` this is bit-identical to
    :func:`decode_attention` (same projections, same reduction shapes up
    to the query axis).
    """
    dims = attn_dims(cfg)
    dtype = x.dtype
    q = project_q(cfg, p, x, positions, use_rope)
    k = repeat_kv(k_cache, dims.n_heads)
    v = repeat_kv(v_cache, dims.n_heads)
    out = sdpa(q, k, v, mask[:, None])  # [B, 1, C, T]
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))


def fused_paged_attention(
    cfg: ModelConfig,
    p: dict,
    x: jnp.ndarray,          # [B, C, D] normed activations (C=1 for step)
    positions: jnp.ndarray,  # [B, C] absolute query positions
    pages_k: jnp.ndarray,    # [n_blocks, ps, Hkv, Dh] physical pool (layer)
    pages_v: jnp.ndarray,
    blocks: jnp.ndarray,     # int32 [B, P] clamped physical block ids
    view_ok: jnp.ndarray,    # bool [B, C, P*ps]
    ring_k: Optional[jnp.ndarray] = None,   # [B, R, Hkv, Dh] staging lanes
    ring_v: Optional[jnp.ndarray] = None,
    ring_ok: Optional[jnp.ndarray] = None,  # bool [B, R]
    use_rope: bool = True,
    impl: str = "auto",
) -> jnp.ndarray:
    """Decode attention through the ``flash_decode_paged`` read kernel.

    The fused twin of :func:`decode_attention` / :func:`masked_chunk_attention`
    over a paged pool: the kernel walks the page table and overlays the
    staging ring inside one softmax, so no gathered view is materialized.
    Projections (``project_q``) and the output einsum are shared with the
    jnp cores — fused and reference differ ONLY in the attention core,
    which the kernel holds to ulp-level fp32 parity (identical greedy
    tokens; DESIGN.md §7).
    """
    from ..kernels import flash_decode_paged

    dtype = x.dtype
    q = project_q(cfg, p, x, positions, use_rope)   # [B, C, Hq, Dh]
    out = flash_decode_paged(q, pages_k, pages_v, blocks, view_ok,
                             ring_k, ring_v, ring_ok, impl=impl)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))


def slot_positions(clen: int, last_pos: int) -> jnp.ndarray:
    """Absolute position stored in each cache slot after writing ``last_pos``.

    Works for both linear caches (slot == position) and SWA rings
    (slot = position % clen): negative results mark not-yet-written slots.
    """
    s = jnp.arange(clen)
    phase = last_pos % clen
    return last_pos - ((phase - s) % clen)


def chunk_attention(
    cfg: ModelConfig,
    p: dict,
    x: jnp.ndarray,          # [B, C, D] normed chunk activations
    positions: jnp.ndarray,  # [B, C] absolute query positions
    k_cache: jnp.ndarray,    # [B, clen, Hkv, Dh] (chunk keys already written)
    v_cache: jnp.ndarray,
    slot_pos: jnp.ndarray,   # [clen] absolute position per slot (<0 invalid)
    use_rope: bool = True,
) -> jnp.ndarray:
    """Chunked-prefill attention: C queries against the full cache.

    Memory is O(C * clen) — this is what makes prefill_32k lowerable
    (C=2048 vs the 32k^2 scores of one-shot prefill).
    """
    dims = attn_dims(cfg)
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
    if "bq" in p:
        q = q + p["bq"].astype(dtype)
    if use_rope:
        q = apply_rope(cfg, q, positions)
    k = repeat_kv(k_cache, dims.n_heads)
    v = repeat_kv(v_cache, dims.n_heads)
    qpos = positions[:, None, :, None]          # [B, 1, C, 1]
    kpos = slot_pos[None, None, None, :]        # [1, 1, 1, clen]
    mask = (kpos <= qpos) & (kpos >= 0)
    if cfg.sliding_window:
        mask &= kpos > qpos - cfg.sliding_window
    out = sdpa(q, k, v, mask)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))


def write_chunk(cache: jnp.ndarray, chunk: jnp.ndarray, start_pos: int) -> jnp.ndarray:
    """Write a [B, C, H, Dh] chunk into cache slots (ring-aware, contiguous).

    Chunk writes are the offload/direct path by construction: they are
    dense slice updates (the paper keeps large/contiguous writes offloaded).
    """
    b, c = chunk.shape[:2]
    clen = cache.shape[1]
    s0 = start_pos % clen
    if c >= clen:
        # chunk covers the whole ring: keep the last clen positions, rolled
        tail = chunk[:, -clen:]
        shift = (start_pos + c) % clen
        return jnp.roll(tail, shift, axis=1) if shift else tail
    if s0 + c <= clen:
        return jax.lax.dynamic_update_slice(cache, chunk, (0, s0, 0, 0))
    first = clen - s0
    cache = jax.lax.dynamic_update_slice(cache, chunk[:, :first], (0, s0, 0, 0))
    return jax.lax.dynamic_update_slice(cache, chunk[:, first:], (0, 0, 0, 0))


def project_kv(
    cfg: ModelConfig, p: dict, x: jnp.ndarray, positions: Optional[jnp.ndarray]
):
    """k, v for cache insertion (decode writes / cross-attn precompute)."""
    dtype = x.dtype
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"].astype(dtype))
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"].astype(dtype))
    if "bk" in p:
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    if positions is not None:
        k = apply_rope(cfg, k, positions)
    return k, v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(cfg: ModelConfig, key: jax.Array, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "wi": (jax.random.normal(ks[0], (d, f)) * d ** -0.5).astype(jnp.float32),
        "wo": (jax.random.normal(ks[1], (f, d)) * f ** -0.5).astype(jnp.float32),
    }
    if cfg.activation == SWIGLU:
        p["wg"] = (jax.random.normal(ks[2], (d, f)) * d ** -0.5).astype(jnp.float32)
    return p


def apply_mlp(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    dtype = x.dtype
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dtype))
    if cfg.activation == SWIGLU:
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dtype))
        h = jax.nn.silu(g) * h
    elif cfg.activation == SQUARED_RELU:
        h = jnp.square(jax.nn.relu(h))
    elif cfg.activation == GELU:
        h = jax.nn.gelu(h)
    else:
        raise ValueError(cfg.activation)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dtype))


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------


def init_embed(cfg: ModelConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 2)
    p = {
        "tok": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model)) * 0.02).astype(
            jnp.float32
        )
    }
    if not cfg.tie_embeddings:
        p["head"] = (
            jax.random.normal(ks[1], (cfg.vocab, cfg.d_model))
            * cfg.d_model ** -0.5
        ).astype(jnp.float32)
    return p


def shard_batch(x: jnp.ndarray) -> jnp.ndarray:
    """Constrain a [B, ...] activation to batch sharding over the data axes.

    The embedding table is D-sharded (lookup locality), so its output
    inherits a D-sharded layout; without this constraint the layer scan's
    saved residuals keep that layout and GSPMD falls back to full
    rematerialization (replicating [B, S, D] per layer). One constraint at
    the residual stream's source pins the whole scan to batch sharding.
    """
    mesh = get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    if not dp or x.shape[0] % size:
        return x
    from jax.sharding import PartitionSpec as P

    spec = dp if len(dp) > 1 else dp[0]
    # two-step reshard: batch-shard while KEEPING the last dim sharded, then
    # all-gather the last dim. The direct one-step reshard trips an SPMD
    # partitioner bug ("slice dim size > dynamic slice dimension") on some
    # gather outputs.
    if (
        x.ndim == 3
        and "model" in mesh.axis_names
        and x.shape[-1] % mesh.shape["model"] == 0
    ):
        x = jax.lax.with_sharding_constraint(x, P(spec, None, "model"))
    return jax.lax.with_sharding_constraint(
        x, P(spec, *((None,) * (x.ndim - 1)))
    )


def embed_tokens(cfg: ModelConfig, p: dict, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    # batch-shard the INDICES first: the gather then natively produces a
    # (batch, D-shard) layout, and shard_batch only all-gathers D — without
    # this, resharding the gather's batch dim trips an SPMD replicate-
    # fallback bug on some shapes.
    tokens = shard_batch(tokens)
    return shard_batch(p["tok"].astype(dtype)[tokens])


def lm_logits(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Final logits in float32 (loss numerics).

    TP vocab padding: odd vocab sizes (whisper 51865, granite 49155,
    mamba2 50280) cannot shard over the model axis, which would REPLICATE
    the [B, S, V] fp32 logits on every model rank. Under a mesh, the head
    matrix is zero-padded to the next multiple of the axis and the padded
    lanes are masked to -inf — logsumexp/softmax/argmax are all exact, and
    the logits shard.
    """
    w = p["tok"] if cfg.tie_embeddings else p["head"]
    v = w.shape[0]
    vp = 0
    mesh = get_abstract_mesh()
    if mesh is not None and "model" in mesh.axis_names:
        m = mesh.shape["model"]
        if v % m:
            vp = (v + m - 1) // m * m
    if vp:
        w = jnp.pad(w, ((0, vp - v), (0, 0)))
    logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32), w.astype(jnp.float32))
    if vp:
        from jax.sharding import PartitionSpec as P

        lane = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(lane < v, logits, jnp.float32(-1e30))
        logits = jax.lax.with_sharding_constraint(
            logits, P(*((None,) * (logits.ndim - 1)), "model")
        )
    return logits


def cross_entropy_loss(
    logits: jnp.ndarray, labels: jnp.ndarray, mask: Optional[jnp.ndarray] = None
) -> jnp.ndarray:
    """Mean token cross-entropy; logits [B, S, V] fp32, labels int32 [B, S].

    The gold logit is extracted with a where-iota reduction instead of
    ``take_along_axis``: a gather over the (TP-vocab-sharded) logits would
    force SPMD to replicate them; the masked reduction partitions cleanly
    over the vocab axis (one extra elementwise pass, fused by XLA).
    """
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(
        jnp.where(vocab_iota == labels[..., None], logits, 0.0), axis=-1
    )
    nll = logz - gold
    if mask is not None:
        denom = jnp.maximum(jnp.sum(mask), 1)
        return jnp.sum(nll * mask) / denom
    return jnp.mean(nll)
