"""MoE language model: GQA attention + dual-path expert dispatch per layer.

The dispatch mode ("direct" = paper's offload path, "staged" = unload path,
"adaptive" = decision-module routing with expert-hotness counters) is a
runtime attribute; the adaptive hot-mask is produced by
``repro.core.decision.expert_hot_mask`` from monitor counters carried in the
train/serve state — the paper's frequency policy, verbatim, applied to
expert ids instead of 4 KB pages.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from . import moe as MOE
from .scan import get_scan
from .transformer import cache_slots, direct_kv_write, stack_init, valid_mask

Params = Dict[str, Any]


class MoELM:
    """Decoder-only MoE LM with uRDMA dual-path dispatch."""

    def __init__(self, cfg: ModelConfig, dispatch_mode: str = "staged",
                 unroll: bool = False):
        self.cfg = cfg
        self._scan = get_scan(unroll)
        self.dispatch_mode = dispatch_mode

    def init(self, key: jax.Array, max_seq: int = 0) -> Params:
        cfg = self.cfg
        k_emb, k_blocks = jax.random.split(key)
        return {
            "embed": L.init_embed(cfg, k_emb),
            "blocks": stack_init(partial(MOE.init_moe_block, cfg), k_blocks, cfg.n_layers),
            "ln_f": L.init_norm(cfg),
        }

    # -- full forward --------------------------------------------------------
    def forward_with_stats(
        self,
        params: Params,
        tokens: jnp.ndarray,
        hot_mask: Optional[jnp.ndarray] = None,
        remat: bool = False,
        mode: Optional[str] = None,
    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """-> (logits [B,S,V], aux_loss scalar, expert_load [L, E])."""
        cfg = self.cfg
        mode = mode or self.dispatch_mode
        dtype = jnp.dtype(cfg.dtype)
        b, s = tokens.shape
        x = L.embed_tokens(cfg, params["embed"], tokens, dtype)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        mask = L.causal_mask(s, s, cfg.sliding_window)

        def body(carry, p):
            h, aux_acc = carry
            h, aux, load = MOE.moe_block(cfg, p, h, positions, mask, mode, hot_mask)
            return (h, aux_acc + aux), load

        body_fn = jax.checkpoint(body, prevent_cse=False) if remat else body
        (x, aux), loads = self._scan(body_fn, (x, jnp.zeros((), jnp.float32)), params["blocks"])
        x = L.apply_norm(cfg, params["ln_f"], x)
        return L.lm_logits(cfg, params["embed"], x), aux, loads

    def forward(self, params, tokens, media=None, remat: bool = False, hot_mask=None):
        logits, _, _ = self.forward_with_stats(params, tokens, hot_mask, remat)
        return logits

    def loss(self, params, batch, remat: bool = True, hot_mask=None, mode=None):
        logits, aux, _ = self.forward_with_stats(
            params, batch["tokens"], hot_mask, remat, mode
        )
        ce = L.cross_entropy_loss(logits, batch["labels"], batch.get("loss_mask"))
        return ce + aux

    def loss_with_stats(self, params, batch, remat: bool = True, hot_mask=None, mode=None):
        """Returns (loss, expert_load [L, E]) — load feeds the monitor."""
        logits, aux, loads = self.forward_with_stats(
            params, batch["tokens"], hot_mask, remat, mode
        )
        ce = L.cross_entropy_loss(logits, batch["labels"], batch.get("loss_mask"))
        return ce + aux, loads

    # -- caches ---------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int, dtype=None) -> Params:
        cfg = self.cfg
        dims = L.attn_dims(cfg)
        dtype = dtype or jnp.dtype(cfg.dtype)
        return {
            "k": jnp.zeros((cfg.n_layers, batch, max_seq, dims.n_kv_heads, dims.head_dim), dtype),
            "v": jnp.zeros((cfg.n_layers, batch, max_seq, dims.n_kv_heads, dims.head_dim), dtype),
        }

    def prefill(self, params, tokens, max_seq: int, media=None, hot_mask=None):
        cfg = self.cfg
        mode = self.dispatch_mode
        dtype = jnp.dtype(cfg.dtype)
        b, s = tokens.shape
        x = L.embed_tokens(cfg, params["embed"], tokens, dtype)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        mask = L.causal_mask(s, s, cfg.sliding_window)

        def body(carry, p):
            h = carry
            hn = L.apply_norm(cfg, p["ln1"], h)
            k, v = L.project_kv(cfg, p["attn"], hn, positions)
            h, _, _ = MOE.moe_block(cfg, p, h, positions, mask, mode, hot_mask)
            return h, (k, v)

        x, (ks, vs) = self._scan(body, x, params["blocks"])
        if s < max_seq:
            pad = [(0, 0), (0, 0), (0, max_seq - s), (0, 0), (0, 0)]
            ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
        cache = {"k": ks, "v": vs}
        x = L.apply_norm(cfg, params["ln_f"], x[:, -1:])
        logits = L.lm_logits(cfg, params["embed"], x)[:, 0]
        return logits, cache

    def chunk_prefill(self, params, cache, tokens, start_pos: int, media=None,
                      hot_mask=None):
        """Chunked prefill (see DecoderLM.chunk_prefill) with MoE FFNs."""
        cfg = self.cfg
        mode = self.dispatch_mode
        dtype = jnp.dtype(cfg.dtype)
        b, c = tokens.shape
        x = L.embed_tokens(cfg, params["embed"], tokens, dtype)
        positions = jnp.broadcast_to(
            start_pos + jnp.arange(c, dtype=jnp.int32), (b, c)
        )
        clen = cache["k"].shape[2]
        spos = L.slot_positions(clen, start_pos + c - 1)

        def body(carry, xs):
            h = carry
            p, kc, vc = xs
            hn = L.apply_norm(cfg, p["ln1"], h)
            k_new, v_new = L.project_kv(cfg, p["attn"], hn, positions)
            kc = L.write_chunk(kc, k_new, start_pos)
            vc = L.write_chunk(vc, v_new, start_pos)
            h = h + L.chunk_attention(cfg, p["attn"], hn, positions, kc, vc, spos)
            m, _, _ = MOE.moe_ffn_layer(
                cfg, p["moe"], L.apply_norm(cfg, p["ln2"], h), mode, hot_mask
            )
            return h + m, (kc, vc)

        x, (ks, vs) = self._scan(body, x, (params["blocks"], cache["k"], cache["v"]))
        new_cache = dict(cache, k=ks, v=vs)
        x = L.apply_norm(cfg, params["ln_f"], x[:, -1:])
        logits = L.lm_logits(cfg, params["embed"], x)[:, 0]
        return logits, new_cache

    def decode_step(
        self, params, cache, tokens, pos, kv_writer=direct_kv_write, hot_mask=None
    ):
        cfg = self.cfg
        mode = self.dispatch_mode
        dtype = jnp.dtype(cfg.dtype)
        x = L.embed_tokens(cfg, params["embed"], tokens[:, None], dtype)
        clen = cache["k"].shape[2]
        slots = cache_slots(cfg, pos, clen)
        vmask = valid_mask(cfg, pos, clen)

        def body(carry, xs):
            h = carry
            p, kc, vc = xs
            hn = L.apply_norm(cfg, p["ln1"], h)
            k_new, v_new = L.project_kv(cfg, p["attn"], hn, pos[:, None])
            kc, vc = kv_writer(kc, vc, k_new, v_new, slots)
            h = h + L.decode_attention(cfg, p["attn"], hn, pos, kc, vc, vmask)
            m, _, _ = MOE.moe_ffn_layer(
                cfg, p["moe"], L.apply_norm(cfg, p["ln2"], h), mode, hot_mask
            )
            return h + m, (kc, vc)

        x, (ks, vs) = self._scan(body, x, (params["blocks"], cache["k"], cache["v"]))
        new_cache = dict(cache, k=ks, v=vs)
        x = L.apply_norm(cfg, params["ln_f"], x)
        logits = L.lm_logits(cfg, params["embed"], x)[:, 0]
        return logits, new_cache
