"""Mixture-of-Experts layer with uRDMA-style dual-path dispatch.

This is the PRIMARY integration point of the paper's technique into training
(DESIGN.md §3): dispatching a token to an expert is a "remote write" into a
sharded per-expert buffer, and we provide both paths:

* ``direct``  (paper: OFFLOAD path) — every token-expert assignment is
  scattered straight into the per-expert buffer at a dynamically computed
  slot. Destinations are effectively random (like RDMA writes to arbitrary
  registered regions): XLA lowers this to an unsorted scatter whose cost
  grows with destination irregularity — the MTT-miss analogue.
* ``staged``  (paper: UNLOAD path) — assignments are first SORTED by
  destination expert (the "staging ring": a contiguous, sequentially-written
  buffer), then drained into expert-major order with a regular, perfectly
  tiled copy (the target-CPU memcpy analogue; Pallas kernel
  ``repro.kernels.staged_scatter`` implements the drain on TPU).
* ``adaptive`` — the decision module routes each assignment: assignments to
  HOT experts (heavy-hitter counters, exactly the paper's frequency policy)
  take the direct path — they reuse "cached" destinations; assignments to
  cold experts are staged. Both sub-paths are fixed-shape so the adaptive
  layer jits and shards.

Expert-load counters double as the monitor state: the router updates them
every step, and ``repro.core.policy`` consumes them.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..compat import get_abstract_mesh
from ..configs.base import ModelConfig
from . import layers as L

Params = Dict[str, jnp.ndarray]

DISPATCH_MODES = ("direct", "staged", "adaptive")


def _constrain(x: jnp.ndarray, *spec) -> jnp.ndarray:
    """with_sharding_constraint that no-ops outside a mesh context and
    drops axes that don't divide the corresponding dim."""
    mesh = get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    fixed = []
    for dim, s in zip(x.shape, spec):
        if isinstance(s, str) and s in mesh.axis_names and dim % mesh.shape[s] == 0:
            fixed.append(s)
        else:
            fixed.append(None)
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(x, P(*fixed))


def buf_constraint(buf: jnp.ndarray, n_experts: int) -> jnp.ndarray:
    """Expert-buffer sharding: EP over "model" when E divides it, else the
    capacity dim over "data" (keeps dispatch scatters shard-local-ish)."""
    mesh = get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return buf
    if "model" in mesh.axis_names and n_experts % mesh.shape["model"] == 0:
        return _constrain(buf, "model", None, None)
    return _constrain(buf, None, "data", None)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_moe_mlp(cfg: ModelConfig, key: jax.Array) -> Params:
    """Stacked expert SwiGLU weights + router."""
    d, f, e = cfg.d_model, cfg.expert_d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": (jax.random.normal(ks[0], (d, e)) * d ** -0.5).astype(jnp.float32),
        "wi": (jax.random.normal(ks[1], (e, d, f)) * d ** -0.5).astype(jnp.float32),
        "wg": (jax.random.normal(ks[2], (e, d, f)) * d ** -0.5).astype(jnp.float32),
        "wo": (jax.random.normal(ks[3], (e, f, d)) * f ** -0.5).astype(jnp.float32),
    }


def init_moe_block(cfg: ModelConfig, key: jax.Array) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_norm(cfg),
        "attn": L.init_attention(cfg, k1),
        "ln2": L.init_norm(cfg),
        "moe": init_moe_mlp(cfg, k2),
    }


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------


def route(
    cfg: ModelConfig, p: Params, x: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Top-k routing.

    x: [T, D] flat tokens. Returns (expert_idx [T,K], weights [T,K],
    aux_loss scalar, expert_load [E] int32 — the monitor counter update).
    """
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    # load-balance auxiliary loss (Switch-style): E * sum_e f_e * p_e
    e = cfg.n_experts
    assign_onehot = jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32)
    f_e = jnp.mean(assign_onehot, axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = cfg.router_aux_coef * e * jnp.sum(f_e * p_e)

    load = jnp.zeros((e,), jnp.int32).at[idx.reshape(-1)].add(1)
    return idx, weights.astype(x.dtype), aux, load


def expert_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    """Static per-expert capacity, rounded up to a lane-friendly multiple."""
    c = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, (c + 7) // 8 * 8)


# ---------------------------------------------------------------------------
# Expert FFN over packed buffers
# ---------------------------------------------------------------------------


def expert_ffn(cfg: ModelConfig, p: Params, buf: jnp.ndarray) -> jnp.ndarray:
    """buf [E, C, D] -> [E, C, D], SwiGLU per expert (batched einsum)."""
    dtype = buf.dtype
    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(dtype))
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(dtype))
    h = jax.nn.silu(g) * h
    return jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dtype))


# ---------------------------------------------------------------------------
# Path 1: DIRECT dispatch (offload analogue) — unsorted random scatter
# ---------------------------------------------------------------------------


def dispatch_direct(
    x: jnp.ndarray,
    expert_idx: jnp.ndarray,
    keep: jnp.ndarray,
    capacity: int,
    n_experts: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scatter assignments straight into per-expert buffers.

    x: [T, D]; expert_idx/keep: [T, K]. Returns (buffer [E, C, D],
    slot [T, K] — the slot each kept assignment landed in, -1 if dropped).

    The slot for each assignment is its rank among same-expert assignments
    (computed with a cumulative one-hot — the straightforward "just post the
    write" structure of the offload path). The scatter's destination order
    is data-dependent and unsorted.
    """
    t, k = expert_idx.shape
    flat_e = expert_idx.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)
    onehot = onehot * keep.reshape(-1, 1).astype(jnp.int32)
    ranks = jnp.cumsum(onehot, axis=0) - onehot  # rank among same-expert
    slot = jnp.take_along_axis(ranks, flat_e[:, None], axis=1)[:, 0]
    ok = keep.reshape(-1) & (slot < capacity)
    # sentinel = E*C (out of range -> dropped); -1 would WRAP to the last slot
    flat_dst = jnp.where(ok, flat_e * capacity + slot, n_experts * capacity)
    x_rep = jnp.repeat(x, k, axis=0)  # [T*K, D]
    x_rep = _constrain(x_rep, "data", None)
    buf = jnp.zeros((n_experts * capacity, x.shape[1]), x.dtype)
    buf = buf.at[flat_dst].set(x_rep, mode="drop", unique_indices=True)
    buf = buf_constraint(buf.reshape(n_experts, capacity, x.shape[1]), n_experts)
    return buf, jnp.where(ok, slot, -1).reshape(t, k)


def combine_direct(
    out_buf: jnp.ndarray,
    expert_idx: jnp.ndarray,
    slot: jnp.ndarray,
    weights: jnp.ndarray,
) -> jnp.ndarray:
    """Gather expert outputs back to token order and mix with router weights."""
    e, c, d = out_buf.shape
    flat = out_buf.reshape(e * c, d)
    idx = expert_idx * c + jnp.maximum(slot, 0)
    gathered = flat[idx]  # [T, K, D]
    w = jnp.where(slot >= 0, weights, 0.0)[..., None].astype(out_buf.dtype)
    return jnp.sum(gathered * w, axis=1)


# ---------------------------------------------------------------------------
# Path 2: STAGED dispatch (unload analogue) — sort into staging, then drain
# ---------------------------------------------------------------------------


def dispatch_staged(
    x: jnp.ndarray,
    expert_idx: jnp.ndarray,
    keep: jnp.ndarray,
    capacity: int,
    n_experts: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Sort assignments by destination expert, then drain contiguously.

    The sorted assignment list IS the staging buffer: writes into it are
    sequential appends. The drain to expert-major [E, C, D] order then only
    moves contiguous runs (per-expert segments) — a regular copy that the
    ``staged_scatter`` Pallas kernel performs with dense VMEM tiles.

    Returns (buffer [E, C, D], sort_perm [T*K], slot [T, K]).
    """
    t, k = expert_idx.shape
    tk = t * k
    flat_e = jnp.where(keep.reshape(-1), expert_idx.reshape(-1), n_experts)
    # staging append: stable sort by destination expert
    perm = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[perm]
    # rank within expert segment = position - segment start
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(n_experts + 1))
    slot_sorted = jnp.arange(tk, dtype=jnp.int32) - seg_start[sorted_e]
    ok = (sorted_e < n_experts) & (slot_sorted < capacity)
    # sentinel = E*C (out of range -> dropped); -1 would WRAP to the last slot
    dst = jnp.where(ok, sorted_e * capacity + slot_sorted, n_experts * capacity)

    token_sorted = perm // k
    staged = x[token_sorted]  # [T*K, D] — contiguous staging buffer content
    staged = _constrain(staged, "data", None)
    buf = jnp.zeros((n_experts * capacity, x.shape[1]), x.dtype)
    # drain: destination indices are monotonically increasing — XLA sees a
    # sorted scatter (on TPU: repro.kernels.staged_scatter does this copy).
    buf = buf.at[dst].set(staged, mode="drop", unique_indices=True)
    buf = buf_constraint(buf.reshape(n_experts, capacity, x.shape[1]), n_experts)

    # per-assignment slot in ORIGINAL order (for combine): invert the perm
    inv = jnp.zeros((tk,), jnp.int32).at[perm].set(jnp.arange(tk, dtype=jnp.int32))
    slot_orig = jnp.where(ok, slot_sorted, -1)[inv].reshape(t, k)
    return buf, perm, slot_orig


# ---------------------------------------------------------------------------
# MoE layer with path selection
# ---------------------------------------------------------------------------


def moe_ffn_layer(
    cfg: ModelConfig,
    p: Params,
    x: jnp.ndarray,
    mode: str = "staged",
    hot_mask: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x [B, S, D] -> (y [B, S, D], aux_loss, expert_load [E]).

    mode:
      direct   — offload path for all assignments
      staged   — unload path for all assignments
      adaptive — hot_mask [E] (from the decision module / expert-hotness
                 counters) sends hot-expert assignments direct, cold staged.
    """
    if mode not in DISPATCH_MODES:
        raise ValueError(f"mode {mode!r} not in {DISPATCH_MODES}")
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    tcount = b * s
    idx, weights, aux, load = route(cfg, p, xt)
    cap = expert_capacity(cfg, tcount)
    keep = jnp.ones_like(idx, jnp.bool_)

    # TP expert padding: when E doesn't divide the model axis (granite: 40
    # over TP=16), pad the expert dimension with zero-weight experts so the
    # dispatch buffers shard EP-style instead of replicating. Padded experts
    # never receive assignments (router logits only span the real E).
    n_experts = cfg.n_experts
    mesh = get_abstract_mesh()
    if mesh is not None and "model" in mesh.axis_names:
        m = mesh.shape["model"]
        if n_experts % m:
            n_experts = (n_experts + m - 1) // m * m
    if n_experts != cfg.n_experts:
        epad = n_experts - cfg.n_experts
        p = dict(
            p,
            wi=jnp.pad(p["wi"], ((0, epad), (0, 0), (0, 0))),
            wg=jnp.pad(p["wg"], ((0, epad), (0, 0), (0, 0))),
            wo=jnp.pad(p["wo"], ((0, epad), (0, 0), (0, 0))),
        )
    cfg_moe = cfg if n_experts == cfg.n_experts else dataclasses.replace(
        cfg, n_experts=n_experts
    )

    if mode == "direct":
        buf, slot = dispatch_direct(xt, idx, keep, cap, n_experts)
        out = expert_ffn(cfg_moe, p, buf)
        y = combine_direct(out, idx, slot, weights)
    elif mode == "staged":
        buf, _, slot = dispatch_staged(xt, idx, keep, cap, n_experts)
        out = expert_ffn(cfg_moe, p, buf)
        y = combine_direct(out, idx, slot, weights)
    else:  # adaptive: split assignments by destination hotness
        if hot_mask is None:
            raise ValueError("adaptive mode needs hot_mask [E]")
        assign_hot = hot_mask[idx]  # [T, K]
        # both sub-paths run fixed-shape on disjoint assignment subsets
        buf_h, slot_h = dispatch_direct(xt, idx, assign_hot, cap, n_experts)
        buf_c, _, slot_c = dispatch_staged(xt, idx, ~assign_hot, cap, n_experts)
        out = expert_ffn(cfg_moe, p, buf_h + buf_c)  # disjoint slots -> one FFN pass
        y_h = combine_direct(out, idx, slot_h, weights)
        y_c = combine_direct(out, idx, slot_c, weights)
        y = y_h + y_c

    return y.reshape(b, s, d), aux, load


def moe_block(
    cfg: ModelConfig,
    p: Params,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    mask,
    mode: str = "staged",
    hot_mask: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full MoE transformer block: GQA attention + MoE FFN."""
    x = x + L.attention(cfg, p["attn"], L.apply_norm(cfg, p["ln1"], x), positions, mask=mask)
    h, aux, load = moe_ffn_layer(
        cfg, p["moe"], L.apply_norm(cfg, p["ln2"], x), mode, hot_mask
    )
    return x + h, aux, load
