"""Unified model façade: ``build_model(cfg)`` + abstract input specs.

Every family exposes the same surface:
    model.init(key, max_seq)                 -> params
    model.loss(params, batch)                -> scalar (train objective)
    model.prefill(params, tokens, max_seq, media=...) -> (logits, cache)
    model.decode_step(params, cache, tokens, pos, kv_writer=...) -> (logits, cache)
    model.init_cache(batch, max_seq)         -> cache pytree

``input_specs(cfg, shape)`` produces jax.ShapeDtypeStruct stand-ins for every
model input of an (arch x shape) dry-run cell — weak-type-correct, shardable,
zero allocation.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ENCDEC, HYBRID, MOE, SSM, VLM, ModelConfig, ShapeSpec
from .moe_lm import MoELM
from .ssm_lm import MambaLM, ZambaLM
from .transformer import DecoderLM
from .whisper import WhisperModel


def build_model(cfg: ModelConfig, **kwargs):
    """Family dispatch. kwargs: e.g. dispatch_mode for MoE."""
    if cfg.family == MOE:
        return MoELM(cfg, **kwargs)
    if cfg.family == SSM:
        return MambaLM(cfg, **kwargs)
    if cfg.family == HYBRID:
        return ZambaLM(cfg, **kwargs)
    if cfg.family == ENCDEC:
        return WhisperModel(cfg, **kwargs)
    # dense + vlm share DecoderLM (vlm via cfg.cross_attn_every)
    return DecoderLM(cfg, **kwargs)


def media_spec(cfg: ModelConfig, batch: int, dtype) -> jax.ShapeDtypeStruct:
    """Stub frontend embeddings: VLM patch tokens / whisper audio frames."""
    if cfg.family == VLM:
        return jax.ShapeDtypeStruct((batch, cfg.n_image_tokens, cfg.d_model), dtype)
    if cfg.family == ENCDEC:
        return jax.ShapeDtypeStruct((batch, cfg.n_audio_frames, cfg.d_model), dtype)
    raise ValueError(f"{cfg.name} has no media input")


def needs_media(cfg: ModelConfig) -> bool:
    return cfg.family in (VLM, ENCDEC)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract inputs for one dry-run cell (no device allocation).

    train:   {tokens, labels[, media]}
    prefill: {tokens[, media]}
    decode:  {tokens [B], pos [B], cache (pytree of specs)}
    """
    dtype = jnp.dtype(cfg.dtype)
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)

    if shape.step == "train":
        specs = {"tokens": tok, "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if needs_media(cfg):
            specs["media"] = media_spec(cfg, b, dtype)
        return specs

    if shape.step == "prefill":
        specs = {"tokens": tok}
        if needs_media(cfg):
            specs["media"] = media_spec(cfg, b, dtype)
        return specs

    if shape.step == "decode":
        model = build_model(cfg)
        cache = jax.eval_shape(lambda: model.init_cache(b, s, dtype))
        return {
            "tokens": jax.ShapeDtypeStruct((b,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((b,), jnp.int32),
            "cache": cache,
        }

    raise ValueError(shape.step)


def abstract_params(cfg: ModelConfig, shape: ShapeSpec):
    """ShapeDtypeStruct pytree of params for a cell (via eval_shape)."""
    model = build_model(cfg)
    max_seq = shape.seq_len
    return jax.eval_shape(
        lambda k: model.init(k, max_seq), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
