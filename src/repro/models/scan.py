"""Scan-or-unroll switch for layer stacks.

Models run their layer stacks under ``lax.scan`` by default (O(1) HLO in
depth — required for fast compiles at 100 layers and the 40-cell dry-run).
The roofline prober flips to ``unroll=True`` on depth-reduced configs
because ``compiled.cost_analysis()`` counts a while-loop body ONCE — see
launch/roofline.py for the affine-probe methodology this enables.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def python_scan(body, carry, xs):
    """Drop-in for lax.scan(body, carry, xs) with a python loop (unrolled HLO)."""
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and any(l is not None for l in jax.tree.leaves(ys[0], is_leaf=lambda x: x is None)):
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def get_scan(unroll: bool):
    return python_scan if unroll else lax.scan
