"""Model zoo: dense / MoE / SSM / hybrid / enc-dec / VLM families with a
unified init/loss/prefill/decode API and uRDMA write-engine hooks, plus
the per-request sampling layer the serving engines drive."""
from .model import abstract_params, build_model, input_specs, media_spec, needs_media
from .sampling import SamplingParams, SlotParams, sample_tokens

__all__ = [
    "abstract_params",
    "build_model",
    "input_specs",
    "media_spec",
    "needs_media",
    "SamplingParams",
    "SlotParams",
    "sample_tokens",
]
