"""Model zoo: dense / MoE / SSM / hybrid / enc-dec / VLM families with a
unified init/loss/prefill/decode API and uRDMA write-engine hooks."""
from .model import abstract_params, build_model, input_specs, media_spec, needs_media

__all__ = [
    "abstract_params",
    "build_model",
    "input_specs",
    "media_spec",
    "needs_media",
]
