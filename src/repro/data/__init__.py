from .pipeline import DataConfig, MemmapSource, Pipeline, SyntheticSource

__all__ = ["DataConfig", "MemmapSource", "Pipeline", "SyntheticSource"]
