from ..models.sampling import SamplingParams
from .pipeline import (
    DataConfig,
    MemmapSource,
    Pipeline,
    Request,
    RequestQueue,
    SyntheticSource,
    synthetic_requests,
)

__all__ = [
    "DataConfig",
    "MemmapSource",
    "Pipeline",
    "Request",
    "RequestQueue",
    "SamplingParams",
    "SyntheticSource",
    "synthetic_requests",
]
