"""Sharded, deterministic, restartable token pipeline.

Sources
-------
* ``SyntheticSource`` — deterministic pseudo-text stream (hash of global
  token index), so every (step, host) pair reproduces identical batches
  with no files — used by smoke tests, dry-run-adjacent benches, examples.
* ``MemmapSource``  — flat binary token file (np.memmap), the production
  path: each host reads only its shard's byte range.

Determinism & fault tolerance
-----------------------------
The pipeline is a pure function of (config, step): restart/resume needs no
iterator state beyond the step counter already stored in checkpoints, and a
straggling/preempted host re-reads exactly its shard. ``skip_to(step)``
is O(1). A small background prefetch thread (double buffering) hides host
read latency from the training loop.
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import warnings
from typing import Dict, Iterator, Optional, Sequence, Union

import numpy as np

from ..models.sampling import SamplingParams


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    # sharding over hosts: this host handles [host_index, num_hosts)
    num_hosts: int = 1
    host_index: int = 0
    seed: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


class SyntheticSource:
    """Deterministic token stream: token[i] = splitmix-style hash of i."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        b, s = cfg.host_batch, cfg.seq_len
        # global token offsets for this host's rows at this step
        row0 = step * cfg.global_batch + cfg.host_index * b
        rows = row0 + np.arange(b, dtype=np.int64)[:, None]
        idx = rows * (s + 1) + np.arange(s + 1, dtype=np.int64)[None, :]
        toks = _splitmix(idx + cfg.seed) % cfg.vocab
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


class MemmapSource:
    """Flat int32 token file; rows are drawn round-robin over the file."""

    def __init__(self, cfg: DataConfig, path: str):
        self.cfg = cfg
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.n_rows = (len(self.tokens) - 1) // cfg.seq_len
        if self.n_rows <= 0:
            raise ValueError(f"{path}: too few tokens for seq_len={cfg.seq_len}")

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        b, s = cfg.host_batch, cfg.seq_len
        row0 = step * cfg.global_batch + cfg.host_index * b
        out_t = np.empty((b, s), np.int32)
        out_l = np.empty((b, s), np.int32)
        for i in range(b):
            r = (row0 + i) % self.n_rows
            chunk = self.tokens[r * s : r * s + s + 1]
            out_t[i] = chunk[:-1]
            out_l[i] = chunk[1:]
        return {"tokens": out_t, "labels": out_l}


def _splitmix(x: np.ndarray) -> np.ndarray:
    """64-bit splitmix hash, vectorized (deterministic synthetic tokens)."""
    x = (x.astype(np.uint64) + np.uint64(0x9E3779B97F4A7C15))
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return (x ^ (x >> np.uint64(31))).astype(np.int64)


@dataclasses.dataclass
class Request:
    """One serving request: a prompt plus its per-request
    ``SamplingParams`` (temperature / top-k / top-p / max_tokens / stop
    set / seed — ``repro.models.sampling``).

    ``params.max_tokens`` counts EVERY emitted token, including the one
    the prefill produces; the scheduler retires the request after
    ``max_tokens`` tokens or on a stop token, whichever comes first.
    """

    req_id: int
    prompt: np.ndarray               # int32 [plen]
    params: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    media: Optional[np.ndarray] = None

    def __post_init__(self):
        if isinstance(self.params, int):
            # deprecation shim: PR-3-era code constructed
            # Request(rid, prompt, max_new) with a bare budget in the
            # third slot — coerce it so those scripts keep running
            warnings.warn(
                "Request(..., max_new) is deprecated; pass "
                "params=SamplingParams(max_tokens=...) instead",
                DeprecationWarning, stacklevel=3)
            self.params = SamplingParams(max_tokens=self.params)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[-1])

    @property
    def max_new(self) -> int:
        """Legacy alias for ``params.max_tokens``."""
        return self.params.max_tokens


class RequestQueue:
    """FIFO request queue feeding the serve scheduler's admissions.

    Arrival order is authoritative: the scheduler scans from the head and
    admits the FIRST request that fits, skipping (``at``/``pop_at``) past
    ones whose resources can't be covered right now — a skipped request
    keeps its queue position and is admitted as soon as it fits, so
    relative order among admissible requests is preserved without
    head-of-line blocking. Host-side and unsynchronized by design —
    admission happens between scan segments on one thread.
    """

    def __init__(self):
        self._q: "collections.deque[Request]" = collections.deque()
        self._next_id = 0

    def __len__(self) -> int:
        return len(self._q)

    def submit(self, prompt, max_new: Optional[int] = None, media=None,
               params: Optional[SamplingParams] = None) -> int:
        """Enqueue one request; returns its id (submission order).

        ``params`` carries the per-request sampling knobs; the legacy
        ``max_new`` argument overrides ``params.max_tokens`` when given
        (``submit(prompt, 8)`` keeps meaning what it always did).
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if params is None:
            if max_new is None:
                raise ValueError("submit needs max_new or params")
            params = SamplingParams(max_tokens=int(max_new))
        elif max_new is not None:
            params = dataclasses.replace(params, max_tokens=int(max_new))
        rid = self._next_id
        self._next_id += 1
        self._q.append(Request(rid, prompt, params, media))
        return rid

    def peek(self) -> Request:
        return self._q[0]

    def pop(self) -> Request:
        return self._q.popleft()

    def at(self, i: int) -> Request:
        """The i-th waiting request (0 = head), submission order."""
        return self._q[i]

    def pop_at(self, i: int) -> Request:
        """Remove and return the i-th waiting request; later requests keep
        their relative order (the scheduler's skip-ahead admission)."""
        if i == 0:
            return self._q.popleft()
        self._q.rotate(-i)
        req = self._q.popleft()
        self._q.rotate(i)
        return req


def synthetic_requests(
    n: int,
    prompt_len,
    vocab: int,
    max_new: int,
    seed: int = 0,
    media_shape=None,
    params: Union[SamplingParams, Sequence[SamplingParams], None] = None,
) -> RequestQueue:
    """Deterministic request workload (splitmix-hashed prompts — the same
    generator the synthetic training source uses, so every (seed, i) pair
    reproduces the same request on any host).

    ``prompt_len`` may be a sequence: request ``i`` gets length
    ``prompt_len[i % len(prompt_len)]`` — the mixed long/short-prompt
    workload the chunked-prefill scheduler and its benchmark exercise
    (request ``i``'s prompt is the same for any surrounding mix).

    ``params`` threads per-request SamplingParams through the queue: one
    object applies to every request, a sequence assigns request ``i``
    ``params[i % len(params)]`` (cycled like ``prompt_len``), and each
    request's own ``max_tokens`` is honored; ``max_new`` applies only
    when ``params`` is None. Prompt generation is independent of the
    sampling mix either way.
    """
    plens = (list(prompt_len) if hasattr(prompt_len, "__len__")
             else [int(prompt_len)])
    plist = (None if params is None
             else (list(params) if hasattr(params, "__len__")
                   else [params]))
    q = RequestQueue()
    for i in range(n):
        plen = int(plens[i % len(plens)])
        idx = np.arange(plen, dtype=np.int64) + i * plen
        prompt = (_splitmix(idx + seed) % vocab).astype(np.int32)
        media = None
        if media_shape is not None:
            flat = _splitmix(
                np.arange(int(np.prod(media_shape)), dtype=np.int64)
                + (seed + 1) * (i + 1)
            )
            media = (flat % 1024).astype(np.float32).reshape(media_shape) / 512.0 - 1.0
        if plist is None:
            q.submit(prompt, max_new, media=media)
        else:
            q.submit(prompt, media=media, params=plist[i % len(plist)])
    return q


class Pipeline:
    """Prefetching iterator over a source, restartable at any step."""

    def __init__(self, source, start_step: int = 0, prefetch: int = 2):
        self.source = source
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _worker(self, stop: threading.Event, q: "queue.Queue"):
        # stop/q are BOUND at thread start: a worker that outlives its
        # epoch (join timeout in stop()) keeps seeing its own set event and
        # its own orphaned queue, and can never publish stale batches into
        # a restarted pipeline
        s = self.step
        while not stop.is_set():
            batch = self.source.batch_at(s)
            while not stop.is_set():
                try:
                    q.put((s, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def start(self) -> "Pipeline":
        self._thread = threading.Thread(
            target=self._worker, args=(self._stop, self._q), daemon=True
        )
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            # unblock a put() stuck on a full queue
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=2)
            self._thread = None
            # retire this epoch's queue: the worker may still complete one
            # put() on its way out (or be alive past the join timeout) —
            # a restart (skip_to) must never serve a stale pre-skip batch
            self._q = queue.Queue(maxsize=self._q.maxsize)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        if self._thread is None:
            batch = self.source.batch_at(self.step)
            self.step += 1
            return batch
        s, batch = self._q.get()
        self.step = s + 1
        return batch

    def skip_to(self, step: int):
        """O(1) resume: the source is a pure function of step."""
        was_running = self._thread is not None
        if was_running:
            self.stop()
            self._stop = threading.Event()
        self.step = step
        if was_running:
            self.start()
