"""Benchmark harness entrypoint: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig3,engine,...]

Prints ``name,value,unit`` CSV rows (stable format for EXPERIMENTS.md).
"""
from __future__ import annotations

import argparse
import sys
import time

SUITES = ("fig3", "engine", "policy_overhead", "moe_dispatch",
          "kernel_bench", "serve_modes")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(SUITES))
    args = ap.parse_args()
    chosen = args.only.split(",") if args.only else list(SUITES)

    print("name,value,unit")
    failures = 0
    for name in chosen:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            for row, value, unit in mod.run():
                print(f"{row},{value:.4g},{unit}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}/ERROR,{type(e).__name__}: {e},-", file=sys.stderr)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        raise SystemExit(f"{failures} suite(s) failed")


if __name__ == "__main__":
    main()
