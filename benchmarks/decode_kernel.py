"""Decode read-core microbenchmark: fused ``flash_decode_paged`` vs the
reference read path (page-table gather + ring concat + jnp SDPA).

Measures ONLY the attention read core — the thing the fused kernel
replaces — at a serving-representative paged shape (multi-slot step
decode plus a chunked mixed-phase slab), on live pool/ring/block-table
operands. Reports per-call wall time for both implementations and the
parity between them (``max_abs_diff`` against the jnp oracle must stay
at fp32 ulp level — ``parity_ok`` is the CI-gated correctness bit; see
DESIGN.md §7 for why the bound is ulps, not bits).

On CPU the kernel runs in INTERPRET mode (``backend: "cpu-interpret"``
in the row) — a validation lane, not a serving path, so the fused
timing there is an emulation cost, NOT the paper's claim; the
compiled-backend numbers are the ones that carry the fused >= reference
story. The gate therefore rides on the per-host ``*_ms`` trajectories
(same host class only) and ``parity_ok``, never on a cross-host ratio.

CLI::

    PYTHONPATH=src python benchmarks/decode_kernel.py \
        [--json out.json] [--merge-into BENCH_serve.json] [--repeats 20]

``--merge-into`` inserts/replaces the ``decode_kernel`` section of an
existing serve_modes report (or baseline) in place, so one combined
document flows into ``benchmarks/check_regression.py``.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _bench(fn, args, repeats: int) -> float:
    """Best-of-``repeats`` wall ms for one jitted call (warm)."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def _operands(rng, b, c, hq, hkv, d, nb, ps, p, r):
    """Serving-shaped operands: a warm pool, a partially-filled ring, and
    a block table with the allocation raggedness real slots have."""
    q = jnp.asarray(rng.randn(b, c, hq, d), jnp.float32)
    pk = jnp.asarray(rng.randn(nb, ps, hkv, d), jnp.float32)
    pv = jnp.asarray(rng.randn(nb, ps, hkv, d), jnp.float32)
    table = np.full((b, p), -1, np.int64)
    perm = rng.permutation(nb)
    n = 0
    for bi in range(b):  # slots at different fill depths
        k = 1 + (bi * (p - 1)) // max(b - 1, 1)
        table[bi, :k] = perm[n:n + k]
        n += k
    blocks = jnp.asarray(np.maximum(table, 0), jnp.int32)
    view_ok = jnp.asarray(
        np.repeat(table >= 0, ps, axis=1)[:, None, :]
        & (rng.rand(b, c, p * ps) > 0.1))
    ring_k = jnp.asarray(rng.randn(b, r, hkv, d), jnp.float32)
    ring_v = jnp.asarray(rng.randn(b, r, hkv, d), jnp.float32)
    ring_ok = jnp.asarray(np.arange(r)[None, :] < rng.randint(1, r + 1, (b, 1)))
    return q, pk, pv, blocks, view_ok, ring_k, ring_v, ring_ok


def bench_decode_kernel(repeats: int = 20) -> dict:
    rng = np.random.RandomState(11)
    shape = dict(b=8, hq=8, hkv=4, d=64, nb=64, ps=8, p=8, r=8)

    fused = jax.jit(lambda *a: ops.flash_decode_paged(*a, impl="auto"))
    reference = jax.jit(ref.flash_decode_paged_ref)

    row = {
        "backend": jax.default_backend() + (
            "-interpret" if jax.default_backend() == "cpu" else ""),
        **shape,
    }
    worst = 0.0
    for phase, c in (("step", 1), ("chunk", 8)):
        args = _operands(rng, c=c, **shape)
        row[f"fused_{phase}_ms"] = round(_bench(fused, args, repeats), 3)
        row[f"reference_{phase}_ms"] = round(
            _bench(reference, args, repeats), 3)
        diff = float(jnp.max(jnp.abs(fused(*args) - reference(*args))))
        worst = max(worst, diff)
    row["max_abs_diff"] = worst
    # fp32 ulp-level bound with 10x margin (DESIGN.md §7); real kernel
    # bugs (wrong page, stale mask, dropped ring lane) miss by >= 1e-3
    row["parity_ok"] = bool(worst < 2e-6)
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="write the JSON report here")
    ap.add_argument("--merge-into", default=None,
                    help="insert the decode_kernel section into this "
                         "existing report/baseline file in place")
    ap.add_argument("--repeats", type=int, default=20)
    args = ap.parse_args()

    row = bench_decode_kernel(repeats=args.repeats)
    report = {"env": {"machine": platform.machine(),
                      "cpus": os.cpu_count()},
              "decode_kernel": row}
    print(json.dumps(report, indent=2))
    if args.json:
        with open(args.json, "w") as f:
            f.write(json.dumps(report, indent=2) + "\n")
    if args.merge_into:
        doc = {}
        if os.path.exists(args.merge_into):
            with open(args.merge_into) as f:
                doc = json.load(f)
        doc.setdefault("env", report["env"])
        doc["decode_kernel"] = row
        with open(args.merge_into, "w") as f:
            f.write(json.dumps(doc, indent=2) + "\n")
    if not row["parity_ok"]:
        raise SystemExit(
            f"fused/reference parity broke: max_abs_diff={row['max_abs_diff']}")


if __name__ == "__main__":
    main()
