"""Paper Fig. 3 reproduction: RTT latency vs region count for the
offload / unload / adaptive policies (calibrated simulator + REAL policy
code). One row per (policy, region_count) point."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import FIG3_CLAIMS, PAPER_WORKLOAD
from repro.core.monitor import ExactMonitor
from repro.core.policy import AlwaysOffload, AlwaysUnload, FrequencyPolicy, HintPolicy
from repro.core.simulator import sweep_point

N_WRITES, WARMUP = 60_000, 6_000
REGION_COUNTS = (1, 2**6, 2**12, 2**14, 2**17, 2**20)


def run() -> list:
    rows = []
    for r in REGION_COUNTS:
        key = jax.random.key(r)
        off, _ = sweep_point(key, r, N_WRITES, WARMUP, AlwaysOffload())
        un, _ = sweep_point(key, r, N_WRITES, WARMUP, AlwaysUnload())
        hot = jnp.zeros((r,), bool).at[: min(PAPER_WORKLOAD.adaptive_top_k, r)].set(True)
        ad, _ = sweep_point(key, r, N_WRITES, WARMUP, HintPolicy(hot_regions=hot))
        mon = ExactMonitor(n_regions=r)
        fr, _ = sweep_point(key, r, N_WRITES, WARMUP,
                            FrequencyPolicy(monitor=mon, threshold=3), mon)
        rows += [
            (f"fig3/offload/r={r}", off, "us"),
            (f"fig3/unload/r={r}", un, "us"),
            (f"fig3/adaptive_hint/r={r}", ad, "us"),
            (f"fig3/adaptive_freq/r={r}", fr, "us"),
        ]
    # headline claims
    off20, _ = sweep_point(jax.random.key(0), 2**20, N_WRITES, WARMUP, AlwaysOffload())
    un20, _ = sweep_point(jax.random.key(0), 2**20, N_WRITES, WARMUP, AlwaysUnload())
    rows.append(("fig3/improvement_at_2e20", 100 * (1 - un20 / off20), "%"))
    rows.append(("fig3/paper_claim", 100 * FIG3_CLAIMS["improvement_at_2e20"], "%"))
    return rows
