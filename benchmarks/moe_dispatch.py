"""MoE dispatch path comparison: direct (offload) vs staged (unload) vs
adaptive, as (a) CPU wall time on the reduced config and (b) compiled HLO
FLOPs/bytes on the single-device lowering — the cost structure the paper's
decision module trades off."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import moe as MOE


def run() -> list:
    cfg = get_config("granite-moe-3b-a800m").reduced()
    p = MOE.init_moe_mlp(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (8, 128, cfg.d_model))
    hot = jnp.zeros((cfg.n_experts,), bool).at[:2].set(True)
    rows = []
    for mode in ("direct", "staged", "adaptive"):
        hk = hot if mode == "adaptive" else None

        @jax.jit
        def f(x, hk=hk, mode=mode):
            y, aux, load = MOE.moe_ffn_layer(cfg, p, x, mode, hk)
            return y

        y = f(x)
        jax.block_until_ready(y)
        t0 = time.perf_counter()
        for _ in range(30):
            y = f(x)
        jax.block_until_ready(y)
        rows.append((f"moe/{mode}_wall_us", (time.perf_counter() - t0) / 30 * 1e6, "us"))

        ca = jax.jit(f).lower(x).compile().cost_analysis()
        rows.append((f"moe/{mode}_hlo_mflops", ca.get("flops", 0) / 1e6, "MF"))
        rows.append((f"moe/{mode}_hlo_mbytes", ca.get("bytes accessed", 0) / 1e6, "MB"))
    return rows
