"""Serving write-mode comparison: direct vs staged vs adaptive KV writes
through the real serve engine (reduced model, CPU wall time per decode
step + path statistics). The framework-level analogue of Fig. 3.

Each mode is measured twice:
  *_ms_per_step       the device-resident decode (ONE jitted lax.scan —
                      drains, routing, telemetry all on device)
  *_ref_ms_per_step   the seed's per-step Python loop (one dispatch + host
                      telemetry round-trips per token), kept as
                      ``ServeEngine.decode_reference``
and the speedup is reported as ``*_scan_speedup``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeConfig, ServeEngine


def _time_generate(eng, prompt, n, reference):
    toks = eng.generate(prompt, n, reference=reference)
    jax.block_until_ready(toks)
    t0 = time.perf_counter()
    toks = eng.generate(prompt, n, reference=reference)
    jax.block_until_ready(toks)
    return (time.perf_counter() - t0) / n * 1e3


def run() -> list:
    cfg = get_config("h2o-danube-3-4b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), 96)
    prompt = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab)
    rows = []
    for mode in ("direct", "staged", "adaptive"):
        def fresh():
            return ServeEngine(model, params, ServeConfig(
                max_seq=96, write_mode=mode, ring_size=8, page_size=8,
                hot_threshold=12,
            ))

        eng = fresh()
        dt = _time_generate(eng, prompt, 24, reference=False)
        rows.append((f"serve/{mode}_ms_per_step", dt, "ms"))
        total = eng.stats["direct_writes"] + eng.stats["staged_writes"]
        if total:
            rows.append((f"serve/{mode}_staged_frac",
                         eng.stats["staged_writes"] / total, "x"))

        dt_ref = _time_generate(fresh(), prompt, 24, reference=True)
        rows.append((f"serve/{mode}_ref_ms_per_step", dt_ref, "ms"))
        rows.append((f"serve/{mode}_scan_speedup", dt_ref / dt, "x"))
    return rows
