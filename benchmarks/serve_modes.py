"""Serving write-mode + scheduler comparison through the real engines.

Two benchmark families:

* write modes (the framework-level analogue of Fig. 3): direct vs staged
  vs adaptive KV writes through ``ServeEngine``, each measured as the
  device-resident scan (``*_ms_per_step``) and the seed's per-step Python
  loop (``*_ref_ms_per_step``), speedup = ``*_scan_speedup``.
* continuous batching (``--batched`` / always part of ``run()``): the
  slot-scheduler (``BatchedServeEngine``, batch 8 over the paged pool)
  vs SEQUENTIAL per-request decode (the same scheduler pinned to one
  slot), same request stream. Reports tok/s for both, the speedup, and
  whether the outputs are bit-identical (they must be: batching is a
  throughput optimization, not a sampling change).
* chunked prefill (``--chunked``): mixed-phase scheduling (prompts
  prefilled in chunks INSIDE the decode scan) vs the admission-blocking
  engine at equal slot count, on a mixed long/short-prompt workload.
  Reports time-to-first-token (mean/p95) and tok/s for both, plus
  bit-identity against sequential decode.

CLI:  PYTHONPATH=src python benchmarks/serve_modes.py --batched --chunked \
          [--json out.json] [--slots 8] [--requests 16]
prints one JSON document (stable keys — CI gates it against the committed
``BENCH_serve.json`` baseline via ``benchmarks/check_regression.py``).
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

import jax
import numpy as np

from repro.data import synthetic_requests
from repro.serve import (
    Engine,
    EngineConfig,
    ServeConfig,
    ServeEngine,
    build_model_and_params,
)


def _time_generate(eng, prompt, n, reference):
    toks = eng.generate(prompt, n, reference=reference)
    jax.block_until_ready(toks)
    t0 = time.perf_counter()
    toks = eng.generate(prompt, n, reference=reference)
    jax.block_until_ready(toks)
    return (time.perf_counter() - t0) / n * 1e3


def _one_pass(eng, mk_queue):
    """One timed serve pass on a warm engine: (outputs, tok/s, ttft).
    ``ttft`` maps req_id -> seconds from serve start to the request's
    first emitted token."""
    eng.reset()
    queue = mk_queue()
    t0 = time.perf_counter()
    outputs = eng.serve(queue)
    dt = time.perf_counter() - t0
    return outputs, sum(len(t) for t in outputs.values()) / dt, dict(eng.ttft)


def _serve_timed(eng, mk_queue, repeats=5):
    """(outputs, tok/s, ttft) on a warm engine: one compile pass, then
    best-of-``repeats`` timed passes — background load only ever slows a
    pass down, so best-of is the low-variance estimator the 15% CI gate
    needs. The TTFT dict comes from the pass with the lowest mean TTFT,
    independently of the throughput pick."""
    eng.serve(mk_queue())
    best_tps, best_ttft, outputs = 0.0, None, None
    for _ in range(repeats):
        outputs, tps, ttft = _one_pass(eng, mk_queue)
        best_tps = max(best_tps, tps)
        if best_ttft is None or (np.mean(list(ttft.values()))
                                 < np.mean(list(best_ttft.values()))):
            best_ttft = ttft
    return outputs, best_tps, best_ttft


def bench_batched(
    arch: str = "stablelm-1.6b",
    n_slots: int = 8,
    n_requests: int = 16,
    prompt_len: int = 16,
    max_new: int = 49,
    write_mode: str = "direct",
    segment_len: int = 16,
) -> dict:
    """Continuous batching vs sequential per-request decode (same model,
    same requests, same paged substrate — only the slot count differs)."""
    max_seq = prompt_len + max_new + 8
    cfg, model, params = build_model_and_params(arch, max_seq)
    mk_queue = lambda: synthetic_requests(  # noqa: E731
        n_requests, prompt_len, cfg.vocab, max_new, seed=11)

    def mk_engine(slots):
        return Engine.from_config(EngineConfig(
            max_seq=max_seq, n_slots=slots, segment_len=segment_len,
            path=write_mode, page_size=8,
        ), model, params)

    out_b, tps_b, _ = _serve_timed(mk_engine(n_slots), mk_queue)
    out_s, tps_s, _ = _serve_timed(mk_engine(1), mk_queue)
    identical = (
        set(out_b) == set(out_s)
        and all(np.array_equal(out_b[r], out_s[r]) for r in out_b)
    )
    return {
        "arch": arch,
        "write_mode": write_mode,
        "n_slots": n_slots,
        "n_requests": n_requests,
        "tokens_per_request": max_new,
        "batched_tok_s": round(tps_b, 2),
        "sequential_tok_s": round(tps_s, 2),
        "batched_speedup": round(tps_b / tps_s, 3),
        "bit_identical": bool(identical),
    }


def _ttft_ms(ttft: dict) -> dict:
    vals = np.asarray(sorted(ttft.values())) * 1e3
    return {
        "mean": round(float(vals.mean()), 2),
        "p95": round(float(np.percentile(vals, 95)), 2),
    }


def _serve_timed_paired(eng_a, eng_b, mk_queue, repeats=5):
    """Best-of-``repeats`` for TWO engines with their passes INTERLEAVED
    (A, B, A, B, ...), so background-load swings hit both sides of the
    comparison — the gated ratio metrics stay stable even when absolute
    numbers drift."""
    eng_a.serve(mk_queue())
    eng_b.serve(mk_queue())
    results = []
    for eng in (eng_a, eng_b):
        results.append({"tps": 0.0, "ttft": None, "out": None, "eng": eng})
    for _ in range(repeats):
        for res in results:
            out, tps, ttft = _one_pass(res["eng"], mk_queue)
            res["out"] = out
            res["tps"] = max(res["tps"], tps)
            if res["ttft"] is None or (np.mean(list(ttft.values()))
                                       < np.mean(list(res["ttft"].values()))):
                res["ttft"] = ttft
    a, b = results
    return (a["out"], a["tps"], a["ttft"]), (b["out"], b["tps"], b["ttft"])


def bench_chunked(
    arch: str = "stablelm-1.6b",
    n_slots: int = 4,
    n_requests: int = 24,
    long_prompt: int = 64,
    short_prompt: int = 8,
    max_new: int = 17,
    chunk_size: int = 32,
    segment_len: int = 4,
) -> dict:
    """Mixed-phase chunked prefill vs the admission-blocking engine, equal
    slot count, on a mixed long/short-prompt workload (every 4th request
    carries the long prompt — the stream the monolithic host-side prefill
    stalls on; 6 admission waves over 4 slots make the stall recurrent).
    Sequential decode (one slot, blocking) is the bit-parity oracle:
    chunking must change WHEN tokens appear, never WHICH."""
    max_seq = long_prompt + max_new + 8
    cfg, model, params = build_model_and_params(arch, max_seq)
    plens = [long_prompt] + [short_prompt] * 3
    mk_queue = lambda: synthetic_requests(  # noqa: E731
        n_requests, plens, cfg.vocab, max_new, seed=11)

    def mk_engine(slots, chunked):
        return Engine.from_config(EngineConfig(
            max_seq=max_seq, n_slots=slots, segment_len=segment_len,
            page_size=8, chunked=chunked, chunk_size=chunk_size,
        ), model, params)

    (out_c, tps_c, ttft_c), (out_b, tps_b, ttft_b) = _serve_timed_paired(
        mk_engine(n_slots, True), mk_engine(n_slots, False), mk_queue)
    out_s, _, _ = _serve_timed(mk_engine(1, False), mk_queue)
    identical = (
        set(out_c) == set(out_b) == set(out_s)
        and all(np.array_equal(out_c[r], out_s[r]) for r in out_c)
        and all(np.array_equal(out_b[r], out_s[r]) for r in out_b)
    )
    tc, tb = _ttft_ms(ttft_c), _ttft_ms(ttft_b)
    return {
        "arch": arch,
        "n_slots": n_slots,
        "n_requests": n_requests,
        "long_prompt": long_prompt,
        "short_prompt": short_prompt,
        "chunk_size": chunk_size,
        "tokens_per_request": max_new,
        "chunked_tok_s": round(tps_c, 2),
        "blocking_tok_s": round(tps_b, 2),
        "chunked_ttft_ms": tc["mean"],
        "chunked_ttft_p95_ms": tc["p95"],
        "blocking_ttft_ms": tb["mean"],
        "blocking_ttft_p95_ms": tb["p95"],
        "ttft_speedup": round(tb["mean"] / tc["mean"], 3),
        "bit_identical": bool(identical),
    }


def run() -> list:
    cfg, model, params = build_model_and_params("h2o-danube-3-4b", 96)
    prompt = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab)
    rows = []
    for mode in ("direct", "staged", "adaptive"):
        def fresh():
            # the dense per-request engine IS the thing measured here
            # (jitted scan vs the seed's per-step reference loop), so it
            # is constructed directly; _warn=False keeps the deprecation
            # shim quiet in benchmark output
            return ServeEngine(model, params, ServeConfig(
                max_seq=96, write_mode=mode, ring_size=8, page_size=8,
                hot_threshold=12,
            ), _warn=False)

        eng = fresh()
        dt = _time_generate(eng, prompt, 24, reference=False)
        rows.append((f"serve/{mode}_ms_per_step", dt, "ms"))
        total = eng.stats["direct_writes"] + eng.stats["staged_writes"]
        if total:
            rows.append((f"serve/{mode}_staged_frac",
                         eng.stats["staged_writes"] / total, "x"))

        dt_ref = _time_generate(fresh(), prompt, 24, reference=True)
        rows.append((f"serve/{mode}_ref_ms_per_step", dt_ref, "ms"))
        rows.append((f"serve/{mode}_scan_speedup", dt_ref / dt, "x"))

    # continuous batching (smaller stream than the CLI default: the suite
    # runner favors breadth over statistics)
    b = bench_batched(n_slots=4, n_requests=6, max_new=17, segment_len=8)
    rows.append(("serve/batched_tok_s", b["batched_tok_s"], "tok/s"))
    rows.append(("serve/sequential_tok_s", b["sequential_tok_s"], "tok/s"))
    rows.append(("serve/batched_speedup", b["batched_speedup"], "x"))
    rows.append(("serve/batched_bit_identical", float(b["bit_identical"]), "bool"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batched", action="store_true",
                    help="run the continuous-batching throughput comparison")
    ap.add_argument("--chunked", action="store_true",
                    help="run the chunked-prefill TTFT/throughput comparison "
                         "on its PINNED mixed long/short-prompt workload (the "
                         "CI-gated trajectory; --slots/--requests/--prompt-len/"
                         "--max-new/--write-mode apply to --batched only)")
    ap.add_argument("--json", default=None, help="write the JSON report here")
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=49)
    ap.add_argument("--write-mode", default="direct",
                    choices=("direct", "staged", "adaptive"))
    args = ap.parse_args()

    if args.batched or args.chunked:
        # host-class fingerprint: check_regression.py gates the absolute
        # tok/s / TTFT metrics only when baseline and report come from the
        # same class (ratios + bit-identity are gated unconditionally)
        report = {"env": {"machine": platform.machine(),
                          "cpus": os.cpu_count()}}
        if args.batched:
            report["batched"] = bench_batched(
                arch=args.arch, n_slots=args.slots, n_requests=args.requests,
                prompt_len=args.prompt_len, max_new=args.max_new,
                write_mode=args.write_mode,
            )
        if args.chunked:
            report["chunked"] = bench_chunked(arch=args.arch)
    else:
        report = {name: {"value": val, "unit": unit}
                  for name, val, unit in run()}
    doc = json.dumps(report, indent=2)
    print(doc)
    if args.json:
        with open(args.json, "w") as f:
            f.write(doc + "\n")
    if args.batched and report["batched"]["batched_speedup"] < 1.0:
        sys.exit(1)
    if args.chunked and report["chunked"]["ttft_speedup"] < 1.0:
        sys.exit(1)


if __name__ == "__main__":
    main()
