"""Serving write-mode + scheduler comparison through the real engines.

Two benchmark families:

* write modes (the framework-level analogue of Fig. 3): direct vs staged
  vs adaptive KV writes through ``ServeEngine``, each measured as the
  device-resident scan (``*_ms_per_step``) and the seed's per-step Python
  loop (``*_ref_ms_per_step``), speedup = ``*_scan_speedup``.
* continuous batching (``--batched`` / always part of ``run()``): the
  slot-scheduler (``BatchedServeEngine``, batch 8 over the paged pool)
  vs SEQUENTIAL per-request decode (the same scheduler pinned to one
  slot), same request stream. Reports tok/s for both, the speedup, and
  whether the outputs are bit-identical (they must be: batching is a
  throughput optimization, not a sampling change).

CLI:  PYTHONPATH=src python benchmarks/serve_modes.py --batched \
          [--json out.json] [--slots 8] [--requests 16]
prints one JSON document (stable keys — CI uploads it as the perf
trajectory artifact).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import synthetic_requests
from repro.models import build_model
from repro.serve import BatchConfig, BatchedServeEngine, ServeConfig, ServeEngine


def _time_generate(eng, prompt, n, reference):
    toks = eng.generate(prompt, n, reference=reference)
    jax.block_until_ready(toks)
    t0 = time.perf_counter()
    toks = eng.generate(prompt, n, reference=reference)
    jax.block_until_ready(toks)
    return (time.perf_counter() - t0) / n * 1e3


def _serve_timed(eng, mk_queue):
    """(outputs, tok/s) on a warm engine: one compile pass, one timed pass."""
    eng.serve(mk_queue())
    eng.reset()
    queue = mk_queue()
    t0 = time.perf_counter()
    outputs = eng.serve(queue)
    dt = time.perf_counter() - t0
    n_toks = sum(len(t) for t in outputs.values())
    return outputs, n_toks / dt


def bench_batched(
    arch: str = "stablelm-1.6b",
    n_slots: int = 8,
    n_requests: int = 16,
    prompt_len: int = 16,
    max_new: int = 49,
    write_mode: str = "direct",
    segment_len: int = 16,
) -> dict:
    """Continuous batching vs sequential per-request decode (same model,
    same requests, same paged substrate — only the slot count differs)."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    max_seq = prompt_len + max_new + 8
    params = model.init(jax.random.key(0), max_seq)
    mk_queue = lambda: synthetic_requests(  # noqa: E731
        n_requests, prompt_len, cfg.vocab, max_new, seed=11)

    def mk_engine(slots):
        return BatchedServeEngine(model, params, BatchConfig(
            max_seq=max_seq, n_slots=slots, segment_len=segment_len,
            write_mode=write_mode, page_size=8,
        ))

    out_b, tps_b = _serve_timed(mk_engine(n_slots), mk_queue)
    out_s, tps_s = _serve_timed(mk_engine(1), mk_queue)
    identical = (
        set(out_b) == set(out_s)
        and all(np.array_equal(out_b[r], out_s[r]) for r in out_b)
    )
    return {
        "arch": arch,
        "write_mode": write_mode,
        "n_slots": n_slots,
        "n_requests": n_requests,
        "tokens_per_request": max_new,
        "batched_tok_s": round(tps_b, 2),
        "sequential_tok_s": round(tps_s, 2),
        "batched_speedup": round(tps_b / tps_s, 3),
        "bit_identical": bool(identical),
    }


def run() -> list:
    cfg = get_config("h2o-danube-3-4b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), 96)
    prompt = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab)
    rows = []
    for mode in ("direct", "staged", "adaptive"):
        def fresh():
            return ServeEngine(model, params, ServeConfig(
                max_seq=96, write_mode=mode, ring_size=8, page_size=8,
                hot_threshold=12,
            ))

        eng = fresh()
        dt = _time_generate(eng, prompt, 24, reference=False)
        rows.append((f"serve/{mode}_ms_per_step", dt, "ms"))
        total = eng.stats["direct_writes"] + eng.stats["staged_writes"]
        if total:
            rows.append((f"serve/{mode}_staged_frac",
                         eng.stats["staged_writes"] / total, "x"))

        dt_ref = _time_generate(fresh(), prompt, 24, reference=True)
        rows.append((f"serve/{mode}_ref_ms_per_step", dt_ref, "ms"))
        rows.append((f"serve/{mode}_scan_speedup", dt_ref / dt, "x"))

    # continuous batching (smaller stream than the CLI default: the suite
    # runner favors breadth over statistics)
    b = bench_batched(n_slots=4, n_requests=6, max_new=17, segment_len=8)
    rows.append(("serve/batched_tok_s", b["batched_tok_s"], "tok/s"))
    rows.append(("serve/sequential_tok_s", b["sequential_tok_s"], "tok/s"))
    rows.append(("serve/batched_speedup", b["batched_speedup"], "x"))
    rows.append(("serve/batched_bit_identical", float(b["bit_identical"]), "bool"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batched", action="store_true",
                    help="run the continuous-batching throughput comparison")
    ap.add_argument("--json", default=None, help="write the JSON report here")
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=49)
    ap.add_argument("--write-mode", default="direct",
                    choices=("direct", "staged", "adaptive"))
    args = ap.parse_args()

    if args.batched:
        report = bench_batched(
            arch=args.arch, n_slots=args.slots, n_requests=args.requests,
            prompt_len=args.prompt_len, max_new=args.max_new,
            write_mode=args.write_mode,
        )
    else:
        report = {name: {"value": val, "unit": unit}
                  for name, val, unit in run()}
    doc = json.dumps(report, indent=2)
    print(doc)
    if args.json:
        with open(args.json, "w") as f:
            f.write(doc + "\n")
    if args.batched and report["batched_speedup"] < 1.0:
        sys.exit(1)


if __name__ == "__main__":
    main()
