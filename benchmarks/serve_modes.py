"""Serving write-mode comparison: direct vs staged vs adaptive KV writes
through the real serve engine (reduced model, CPU wall time per decode
step + path statistics). The framework-level analogue of Fig. 3."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeConfig, ServeEngine


def run() -> list:
    cfg = get_config("h2o-danube-3-4b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0), 96)
    prompt = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab)
    rows = []
    for mode in ("direct", "staged", "adaptive"):
        eng = ServeEngine(model, params, ServeConfig(
            max_seq=96, write_mode=mode, ring_size=8, page_size=8,
            hot_threshold=3,
        ))
        toks = eng.generate(prompt, 4)  # warm the jit caches
        t0 = time.perf_counter()
        toks = eng.generate(prompt, 24)
        jax.block_until_ready(toks)
        dt = (time.perf_counter() - t0) / 24 * 1e3
        rows.append((f"serve/{mode}_ms_per_step", dt, "ms"))
        total = eng.stats["direct_writes"] + eng.stats["staged_writes"]
        if total:
            rows.append((f"serve/{mode}_staged_frac",
                         eng.stats["staged_writes"] / total, "x"))
    return rows
