"""Decision-module overhead: ns per request for each policy (paper §3.2
requires answers 'faster than the expected savings' — hundreds of ns).
Jitted, vectorized over a serving-sized request batch."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.decision import DecisionModule
from repro.core.monitor import CMSMonitor, ExactMonitor
from repro.core.policy import AlwaysOffload, FrequencyPolicy, HintPolicy
from repro.core.types import make_write_batch

N = 256  # requests per decision batch


def _bench(dm: DecisionModule, n_iter=200) -> float:
    state = dm.init_state()
    rng = np.random.RandomState(0)
    batch = make_write_batch(jnp.asarray(rng.randint(0, 1 << 16, N), jnp.int32))

    @jax.jit
    def step(state):
        unload, state, _ = dm(state, batch)
        return unload, state

    unload, state = step(state)
    jax.block_until_ready(unload)
    t0 = time.perf_counter()
    for _ in range(n_iter):
        unload, state = step(state)
    jax.block_until_ready(unload)
    return (time.perf_counter() - t0) / n_iter / N * 1e9


def run() -> list:
    exact = ExactMonitor(n_regions=1 << 16)
    cms = CMSMonitor(depth=4, log2_width=12)
    hot = jnp.zeros((1 << 16,), bool).at[:4096].set(True)
    return [
        ("policy/always_offload_ns", _bench(DecisionModule(AlwaysOffload())), "ns"),
        ("policy/hint_ns", _bench(DecisionModule(HintPolicy(hot_regions=hot))), "ns"),
        ("policy/freq_exact_ns",
         _bench(DecisionModule(FrequencyPolicy(monitor=exact, threshold=4), exact)), "ns"),
        ("policy/freq_cms_ns",
         _bench(DecisionModule(FrequencyPolicy(monitor=cms, threshold=4), cms)), "ns"),
    ]
