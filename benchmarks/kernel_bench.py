"""Kernel micro-benchmarks. On this CPU container the Pallas kernels run in
interpret mode (not representative of TPU), so we benchmark the REF oracles'
wall time (XLA:CPU) and report the kernels' analytic TPU roofline times for
the shapes the serving engine uses."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def _wall(f, *args, n=20) -> float:
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e3  # ms


def run() -> list:
    rows = []
    rng = np.random.RandomState(0)

    # staged_scatter drain: KV-page-sized rows
    r, w, n = 512, 2048, 64
    dest = jnp.asarray(rng.randn(r, w), jnp.float32)
    staging = jnp.asarray(rng.randn(n, w), jnp.float32)
    rows_i = jnp.asarray(rng.permutation(r)[:n], jnp.int32)
    valid = jnp.ones((n,), bool)
    f = jax.jit(ref.staged_scatter_ref)
    rows.append(("kern/staged_scatter_ref_ms", _wall(f, dest, staging, rows_i, valid), "ms"))
    bytes_moved = n * w * 4 * 2
    rows.append(("kern/staged_scatter_tpu_roofline_us", bytes_moved / HBM_BW * 1e6, "us"))

    # flash attention prefill tile: chunked-prefill geometry
    b, hq, hkv, s, t, d = 2, 16, 4, 1024, 8192, 128
    q = jnp.asarray(rng.randn(b, hq, s, d), jnp.bfloat16)
    k = jnp.asarray(rng.randn(b, hkv, t, d), jnp.bfloat16)
    v = jnp.asarray(rng.randn(b, hkv, t, d), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    rows.append(("kern/flash_attn_ref_ms", _wall(f, q, k, v, n=5), "ms"))
    flops = 4 * b * hq * s * t * d
    rows.append(("kern/flash_attn_tpu_roofline_us", flops / PEAK_FLOPS * 1e6, "us"))

    # flash decode: 32k cache
    tkv = 32768
    qd = jnp.asarray(rng.randn(8, hq, d), jnp.bfloat16)
    kd = jnp.asarray(rng.randn(8, tkv, hkv, d), jnp.bfloat16)
    vd = jnp.asarray(rng.randn(8, tkv, hkv, d), jnp.bfloat16)
    mask = jnp.ones((8, tkv), bool)
    f = jax.jit(ref.flash_decode_ref)
    rows.append(("kern/flash_decode_ref_ms", _wall(f, qd, kd, vd, mask, n=5), "ms"))
    bytes_kv = 8 * tkv * hkv * d * 2 * 2
    rows.append(("kern/flash_decode_tpu_roofline_us", bytes_kv / HBM_BW * 1e6, "us"))

    # cms monitor hot path
    counts = jnp.zeros((4, 4096), jnp.int32)
    ids = jnp.asarray(rng.randint(0, 1 << 20, 256), jnp.int32)
    f = jax.jit(ref.cms_update_ref)
    rows.append(("kern/cms_update_ref_ms", _wall(f, counts, ids), "ms"))
    return rows
