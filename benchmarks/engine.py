"""RemoteWriteEngine micro-benchmarks (CPU wall time, jitted):
direct vs staged vs adaptive path throughput + the cost of the
beyond-paper ordering-parity machinery. The decision planes are built
from (path, policy) registry names — the same construction surface the
serving engines use."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_umtt, make_write_batch, register
from repro.core.decision import DecisionModule
from repro.core.staged_write import RemoteWriteEngine

R, W, N_BATCH = 1024, 64, 128


def _bench(decision: DecisionModule, n_iter=50) -> float:
    table = register(make_umtt(16), 0, R, stag=7)
    eng = RemoteWriteEngine(decision=decision, ring_capacity=512, width=W)
    state = eng.init_state(table)
    mem = jnp.zeros((R, W))
    rng = np.random.RandomState(0)
    regions = jnp.asarray(rng.zipf(1.5, N_BATCH) % R, jnp.int32)
    payload = jnp.asarray(rng.randn(N_BATCH, W), jnp.float32)
    stags = jnp.full((N_BATCH,), 7, jnp.int32)
    batch = make_write_batch(regions, size=jnp.full((N_BATCH,), W, jnp.int32))

    @jax.jit
    def step(state, mem):
        return eng.write(state, mem, batch, payload, stags)

    state, mem = step(state, mem)  # compile
    jax.block_until_ready(mem)
    t0 = time.perf_counter()
    for _ in range(n_iter):
        state, mem = step(state, mem)
    jax.block_until_ready(mem)
    return (time.perf_counter() - t0) / n_iter / N_BATCH * 1e9  # ns/write


def run() -> list:
    # NOTE: unlike the pre-registry rows, all three decision planes now
    # carry the module-owned ExactMonitor (the paper's monitor sees every
    # write), so direct/staged include one counter update per write —
    # the three rows stay mutually comparable, but not with baselines
    # recorded before the registry migration
    mk = lambda path: DecisionModule.from_names(  # noqa: E731
        path=path, n_regions=R, hot_threshold=4)
    rows = [
        ("engine/direct_ns_per_write", _bench(mk("direct")), "ns"),
        ("engine/staged_ns_per_write", _bench(mk("staged")), "ns"),
        ("engine/adaptive_ns_per_write", _bench(mk("adaptive")), "ns"),
    ]
    return rows
