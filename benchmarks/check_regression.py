"""Benchmark-trajectory CI gate: compare a fresh ``serve_modes.py
--batched --chunked --json`` report against the committed baseline
(``BENCH_serve.json``) and FAIL on regressions.

Rules (direction-aware, per metric key):

* ``*_tok_s`` / ``*_speedup``  higher is better — fail when the current
  value drops more than ``--tolerance`` (default 15%) below baseline.
* ``*_ms`` (TTFT latencies)    lower is better — fail when the current
  value rises more than ``--tolerance`` above baseline.
* ``bit_identical`` / ``parity_ok``  must be true — any sampling/parity
  drift fails outright (correctness, not a tolerance).
  (``parity_ok`` is the decode_kernel row's fused-vs-reference check:
  fp32 ulp-level agreement per DESIGN.md §7.)
* a gated metric present in the baseline but missing from the current
  report fails (schema drift would otherwise silently drop coverage).

Host classes: absolute tok/s and TTFT numbers are machine-dependent, so
they are HARD-gated only when the report's ``env`` fingerprint (machine
arch + cpu count, stamped by serve_modes.py) matches the baseline's.
On a different host class the absolutes are printed report-only and the
machine-invariant ratios (``*_speedup``) plus ``bit_identical`` carry the
gate — refresh the baseline from that runner class to restore the full
gate (the refreshed file re-pins ``env``).

Non-metric keys (workload shape: slot counts, prompt lengths, ...) are
compared for equality and WARN on mismatch — a changed workload makes the
delta table meaningless, so refresh the baseline in the same PR.

Refreshing the baseline (intentional perf/workload changes)::

    PYTHONPATH=src python benchmarks/serve_modes.py --batched --chunked \
        --json BENCH_serve.json

and commit the result. The tolerance absorbs run-to-run noise on one
machine, not machine-class changes: moving CI to slower hardware also
means refreshing the baseline.

Usage::

    python benchmarks/check_regression.py serve_modes.json \
        [--baseline BENCH_serve.json] [--tolerance 0.15]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

HIGHER_BETTER = ("_tok_s", "_speedup")
LOWER_BETTER = ("_ms",)  # every *_ms metric here is a latency

# Baseline comparison points, not the optimizations under test: the
# sequential / admission-blocking engines exist to normalize the headline
# metrics (batched_tok_s, chunked_tok_s, chunked_ttft_*, the speedups).
# A real substrate regression shows in the headline numbers too, so these
# denominators print report-only instead of adding failure modes.
REFERENCE_KEYS = frozenset({
    "sequential_tok_s", "blocking_tok_s",
    "blocking_ttft_ms", "blocking_ttft_p95_ms",
    "reference_step_ms", "reference_chunk_ms",
})


def classify(key: str):
    """'up' (higher better) | 'down' (lower better) | 'bool' | None."""
    if key in ("bit_identical", "parity_ok"):
        return "bool"
    for suf in HIGHER_BETTER:
        if key.endswith(suf):
            return "up"
    for suf in LOWER_BETTER:
        if key.endswith(suf):
            return "down"
    return None


def compare(baseline: dict, current: dict, tolerance: float):
    """-> (rows, failures, warnings); rows are delta-table tuples."""
    rows, failures, warnings = [], [], []
    same_host = baseline.get("env") == current.get("env")
    if not same_host:
        warnings.append(
            f"host class changed ({baseline.get('env')} -> "
            f"{current.get('env')}): absolute tok/s and TTFT metrics are "
            f"report-only; refresh BENCH_serve.json from this runner class "
            f"to restore the full gate")
    for section, base_doc in baseline.items():
        if section == "env":
            continue
        cur_doc = current.get(section)
        if cur_doc is None:
            failures.append(f"{section}: section missing from current report")
            continue
        for key, base in base_doc.items():
            kind = classify(key)
            cur = cur_doc.get(key)
            name = f"{section}.{key}"
            if kind is None:
                if cur != base:
                    warnings.append(
                        f"{name}: workload changed ({base!r} -> {cur!r}); "
                        f"refresh BENCH_serve.json in this PR")
                continue
            if cur is None:
                failures.append(f"{name}: gated metric missing from report")
                continue
            if kind == "bool":
                status = "ok" if cur else "FAIL"
                rows.append((name, base, cur, "-", status))
                if not cur:
                    failures.append(f"{name}: must be true, got {cur}")
                continue
            delta = (cur - base) / base if base else 0.0
            bad = (delta < -tolerance if kind == "up"
                   else delta > tolerance)
            # machine-dependent absolutes only gate on the same host class;
            # ratios (speedups) are machine-invariant and always gate;
            # reference denominators never gate
            gated = ((same_host or key.endswith("_speedup"))
                     and key not in REFERENCE_KEYS)
            status = ("FAIL" if bad else "ok") if gated else "info"
            rows.append((name, base, cur, f"{delta:+.1%}", status))
            if bad and gated:
                failures.append(
                    f"{name}: {base} -> {cur} ({delta:+.1%}, "
                    f"tolerance ±{tolerance:.0%})")
    return rows, failures, warnings


def print_table(rows) -> None:
    if not rows:
        return
    headers = ("metric", "baseline", "current", "delta", "status")
    cols = [max(len(str(v)) for v in [h] + [r[i] for r in rows])
            for i, h in enumerate(headers)]
    fmt = "  ".join(f"{{:<{c}}}" for c in cols)
    print(fmt.format(*headers))
    print(fmt.format(*("-" * c for c in cols)))
    for r in rows:
        print(fmt.format(*(str(v) for v in r)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("report", help="fresh serve_modes.py --json output")
    default_base = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_serve.json")
    ap.add_argument("--baseline", default=default_base)
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="allowed relative regression (default 0.15)")
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.report) as f:
        current = json.load(f)

    rows, failures, warnings = compare(baseline, current, args.tolerance)
    print_table(rows)
    for w in warnings:
        print(f"WARNING: {w}")
    if failures:
        print(f"\n{len(failures)} regression(s) vs {args.baseline}:")
        for msg in failures:
            print(f"  - {msg}")
        sys.exit(1)
    print(f"\nOK: no regression beyond ±{args.tolerance:.0%} "
          f"vs {args.baseline}")


if __name__ == "__main__":
    main()
